//! Compute engines: who executes the worker math.
//!
//! [`NativeEngine`] runs the in-repo linalg (always available, the
//! reference); [`XlaEngine`] executes the AOT HLO artifacts through the
//! PJRT runtime — the production path where Layers 1/2 live.  Both expose
//! the same operations so solvers and the coordinator are engine-generic,
//! and the integration tests assert they agree numerically.

use crate::error::{DapcError, Result};
use crate::linalg::simd::{self, KernelTier, NR};
use crate::linalg::{blas, inverse, qr, triangular, Matrix};
use crate::parallel::ThreadPool;
use crate::partition::pad_to_bucket;
use crate::runtime::{Tensor, XlaExecutor};

/// Which worker initialization to run (Algorithm 1 steps 2-3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    /// Paper's decomposition: QR + backward substitution (eqs. (1)-(4)).
    Qr,
    /// Classical APC: Gram matrix + Gauss-Jordan inverse.
    Classical,
    /// Original-APC fat regime: QR of A^T, genuine projector.
    Fat,
}

impl InitKind {
    pub fn artifact_kind(&self) -> &'static str {
        match self {
            InitKind::Qr => "init_qr",
            InitKind::Classical => "init_classical",
            InitKind::Fat => "init_fat",
        }
    }
}

/// Worker-side init output: initial estimate + projector.
#[derive(Debug, Clone)]
pub struct WorkerInit {
    pub x0: Vec<f32>,
    pub projector: Matrix,
}

/// Right-hand-side-independent state of one partition, retained by warm
/// solver sessions: the eq. (6) projector `P_j` plus whatever
/// factorization of `A_j` re-seeds `x_j(0)` for a fresh `b_j` without
/// repeating the O(l n^2) factorization (`P_j` never depends on `b` —
/// eqs. (1)-(4) build it from `A_j` alone).
pub struct WorkerFactorization {
    /// Eq. (6) projector (RHS-independent by construction).
    pub projector: Matrix,
    /// The same projector prepacked into register-tile A-panels
    /// ([`blas::PrepackedPanels`]) once at factorization time, so the
    /// steady-state epoch loop never re-reads or re-packs the row-major
    /// matrix (the pack-once / stream-forever half of the amortized
    /// regime).
    pub panels: blas::PrepackedPanels,
    /// Factorization state consumed by [`ComputeEngine::seed`].
    pub seed: SeedFactors,
}

/// The retained factorization backing [`ComputeEngine::seed`].  Each
/// variant holds exactly the operands its per-RHS seed path reads, so a
/// warm seed performs the identical arithmetic of the matching cold
/// [`InitKind`] init (bit-identical `x_j(0)`).
pub enum SeedFactors {
    /// Reduced Householder QR of `A_j` (paper eqs. (1)-(4)):
    /// `x0 = R^{-1} Q1^T b` by backward substitution.
    Qr(qr::QrFactors),
    /// f64 Gram inverse `(A_j^T A_j)^{-1}` (classical APC); seeding also
    /// reads the block itself for `A_j^T b`.
    Classical {
        /// Flat row-major n x n inverse in f64.
        ginv: Vec<f64>,
    },
    /// QR of `A_j^T` (fat regime): `x0 = Q (R^T)^{-1} b` by forward
    /// substitution against the pre-transposed `R^T`.
    Fat {
        /// (n x l) semi-orthogonal factor of `A_j^T`.
        q1: Matrix,
        /// (l x l) lower-triangular `R^T`.
        rt: Matrix,
    },
}

/// Reusable buffers for the workspace-reuse round path
/// ([`ComputeEngine::round_into`]): once warmed to a (J, n) shape the
/// steady-state epoch loop performs no heap allocations.
#[derive(Debug, Default, Clone)]
pub struct RoundWorkspace {
    /// One n-length scratch per partition (eq. (6) direction buffer);
    /// the row-dot batched round uses J*k of these, chunked k per
    /// partition, while the prepacked round needs none (diffs are packed
    /// straight into `bpack`).
    pub scratch: Vec<Vec<f32>>,
    /// n-length f64 accumulator for the eq. (7) reduction.
    pub acc: Vec<f64>,
    /// Per-partition packed right-hand-side panels for the prepacked
    /// epoch path ([`ComputeEngine::round_batch_packed_into`]):
    /// [`blas::packed_b_len`]`(n, k)` f32 values each.
    pub bpack: Vec<Vec<f32>>,
    /// Per-partition row-major (n x k) outputs of the packed projector
    /// sweep, scattered back into the per-column estimates.
    pub cbuf: Vec<Vec<f32>>,
}

impl RoundWorkspace {
    /// Workspace pre-sized for a (J, n) round.
    pub fn for_shape(j: usize, n: usize) -> Self {
        let mut ws = Self::default();
        ws.ensure(j, n);
        ws
    }

    /// Grow to fit a (J, n) round; a no-op once warmed to the shape.
    pub fn ensure(&mut self, j: usize, n: usize) {
        if self.scratch.len() < j {
            self.scratch.resize_with(j, Vec::new);
        }
        for s in &mut self.scratch[..j] {
            if s.len() != n {
                s.resize(n, 0.0);
            }
        }
        if self.acc.len() < n {
            self.acc.resize(n, 0.0);
        }
    }

    /// Grow to fit a (J, k, n) row-dot batched round: J*k direction
    /// buffers plus the shared f64 accumulator.
    pub fn ensure_batch(&mut self, j: usize, k: usize, n: usize) {
        self.ensure(j * k, n);
    }

    /// Grow to fit a (J, k, n) prepacked batched round: per partition
    /// one packed B panel set and one (n x k) output buffer, plus the
    /// shared f64 accumulator.  No per-column scratch is needed.
    pub fn ensure_packed(&mut self, j: usize, k: usize, n: usize) {
        if self.acc.len() < n {
            self.acc.resize(n, 0.0);
        }
        let blen = blas::packed_b_len(n, k);
        if self.bpack.len() < j {
            self.bpack.resize_with(j, Vec::new);
        }
        for b in &mut self.bpack[..j] {
            if b.len() != blen {
                b.resize(blen, 0.0);
            }
        }
        if self.cbuf.len() < j {
            self.cbuf.resize_with(j, Vec::new);
        }
        for c in &mut self.cbuf[..j] {
            if c.len() != n * k {
                c.resize(n * k, 0.0);
            }
        }
    }
}

/// Eq. (6) into caller buffers: `out = x + gamma * P (xbar - x)`.
/// `scratch` and `out` must be exactly `x.len()` long.  Shared by the
/// native and parallel engines so their iterates are bit-identical.
pub(crate) fn update_kernel(
    x: &[f32],
    xbar: &[f32],
    p: &Matrix,
    gamma: f32,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    for ((d, &xb), &xi) in scratch.iter_mut().zip(xbar).zip(x) {
        *d = xb - xi;
    }
    blas::gemv(p, scratch, out);
    for (o, &xi) in out.iter_mut().zip(x) {
        *o = xi + gamma * *o;
    }
}

/// Eq. (7) over the index range `[lo, lo + out.len())`: sweeps each `x_j`
/// contiguously (cache-friendly) instead of walking all J vectors per
/// index.  Summation order over j is fixed, so chunking the range across
/// threads cannot change a single output bit.  Generic over the estimate
/// container so batched rounds can pass per-column `&[f32]` views.
pub(crate) fn average_chunk_kernel<S: AsRef<[f32]>>(
    xs: &[S],
    xbar: &[f32],
    eta: f32,
    lo: usize,
    acc: &mut [f64],
    out: &mut [f32],
) {
    let j = xs.len() as f64;
    let len = out.len();
    let eta = eta as f64;
    for a in acc.iter_mut() {
        *a = 0.0;
    }
    for x in xs {
        let x = x.as_ref();
        for (a, &v) in acc.iter_mut().zip(&x[lo..lo + len]) {
            *a += v as f64;
        }
    }
    for ((o, &a), &xb) in out.iter_mut().zip(acc.iter()).zip(&xbar[lo..lo + len])
    {
        *o = (eta * (a / j) + (1.0 - eta) * xb as f64) as f32;
    }
}

/// Eq. (6) for ONE partition over the k right-hand-side columns of a
/// batched session solve, column-blocked: each projector row is widened
/// to f64 once and reused for all k [`blas::dot_wide`] products, so the
/// O(n^2) projector sweep (memory traffic + f32->f64 widening) is paid
/// once per batch instead of once per column.  Per column the arithmetic
/// is exactly [`update_kernel`]'s (`dot`'s fixed 8-lane f64 split in the
/// same order — the `linalg::simd` lane contract guarantees this on both
/// the AVX2 and scalar dispatch paths), so a batch of k is bit-identical
/// to k sequential updates.
///
/// This row-dot sweep is retained as the bitwise oracle for the
/// prepacked epoch path: `simd::microkernel_wide` accumulates every
/// output element in the same fixed 8-lane f64 order over the full
/// depth, so [`ComputeEngine::round_batch_packed_into`] reproduces this
/// kernel bit-for-bit under tier-0.  (An earlier revision claimed packed
/// gemm "would break" batch == sequential equality — true of the
/// f32-accumulating `blas::gemm` microkernel, but not of the wide
/// microkernel built for this path.)
///
/// `xs`/`xbars`/`scratch`/`out` hold k n-length columns.
pub(crate) fn update_batch_kernel(
    xs: &[Vec<f32>],
    xbars: &[Vec<f32>],
    p: &Matrix,
    gamma: f32,
    scratch: &mut [Vec<f32>],
    out: &mut [Vec<f32>],
) {
    let mut wide = vec![0.0f64; p.cols()];
    for ((s, xbar), x) in scratch.iter_mut().zip(xbars).zip(xs) {
        for ((d, &xb), &xi) in s.iter_mut().zip(xbar.iter()).zip(x.iter()) {
            *d = xb - xi;
        }
    }
    for i in 0..p.rows() {
        blas::widen(p.row(i), &mut wide);
        for (o, s) in out.iter_mut().zip(scratch.iter()) {
            o[i] = blas::dot_wide(&wide, s) as f32;
        }
    }
    for (o, x) in out.iter_mut().zip(xs) {
        for (oi, &xi) in o.iter_mut().zip(x.iter()) {
            *oi = xi + gamma * *oi;
        }
    }
}

/// Pack the k batched consensus directions `xbar_c - x_c` of one
/// partition straight into wide-microkernel B-panel layout
/// (`panel[q][p * NR + j]` = column `q*NR + j`, depth index `p`; fringe
/// columns zero-padded) — the diff never materializes as a row-major
/// scratch column.  The subtraction is the identical f32 expression
/// [`update_batch_kernel`] computes, so the packed sweep sees
/// bit-identical inputs.
pub(crate) fn pack_batch_diffs(
    xs: &[Vec<f32>],
    xbars: &[Vec<f32>],
    n: usize,
    bpack: &mut [f32],
) {
    let k = xs.len();
    debug_assert_eq!(k, xbars.len());
    debug_assert!(bpack.len() >= blas::packed_b_len(n, k));
    let col_panels = k.div_ceil(NR);
    for (q, panel) in bpack.chunks_exact_mut(n * NR).enumerate().take(col_panels) {
        for jj in 0..NR {
            let c = q * NR + jj;
            if c < k {
                let (x, xbar) = (&xs[c], &xbars[c]);
                for p in 0..n {
                    panel[p * NR + jj] = xbar[p] - x[p];
                }
            } else {
                for p in 0..n {
                    panel[p * NR + jj] = 0.0;
                }
            }
        }
    }
}

/// Scatter the packed projector sweep's row-major (n x k) output back
/// into per-column estimates and apply the eq. (6) relaxation:
/// `out[c][i] = x[c][i] + gamma * cbuf[i * k + c]` — the same final
/// expression as the row-dot kernel, element for element.
pub(crate) fn scale_batch_from_cbuf(
    xs: &[Vec<f32>],
    cbuf: &[f32],
    gamma: f32,
    k: usize,
    out: &mut [Vec<f32>],
) {
    for (c, (o, x)) in out.iter_mut().zip(xs).enumerate() {
        for (i, (oi, &xi)) in o.iter_mut().zip(x.iter()).enumerate() {
            *oi = xi + gamma * cbuf[i * k + c];
        }
    }
}

/// Bundle a projector with its prepacked panels and seed factors: every
/// retained factorization prepacks `P_j` exactly once, here, so all
/// holders of a [`WorkerFactorization`] (in-process engines, the
/// cluster worker, warm solver sessions) get the packed epoch operand
/// for free.
fn retained(projector: Matrix, seed: SeedFactors) -> WorkerFactorization {
    let panels = blas::PrepackedPanels::from_matrix(&projector);
    WorkerFactorization { projector, panels, seed }
}

/// The ONE factorization kernel behind every engine's
/// [`ComputeEngine::factorize`]: panel-blocked Householder QR (trailing
/// updates fanned over `pool` when one is given) or the f64 Gram
/// inverse.  The pooled and serial QR paths are bit-identical by
/// construction (`linalg::qr` module docs), so cross-engine equality and
/// warm == cold re-seeding hold no matter which engine — at which thread
/// count — performed the factorization.  `tier` selects the f32 kernel
/// tier for the QR sweeps and the fat-regime projector gemm (the
/// engines carry it from [`crate::solver::SolveOptions::kernel_tier`]);
/// every bitwise invariant above holds *within* a tier, and tier-0 is
/// the default everywhere.
pub(crate) fn factorize_kernel(
    kind: InitKind,
    a: &Matrix,
    n_target: usize,
    pool: Option<&ThreadPool>,
    tier: KernelTier,
) -> Result<WorkerFactorization> {
    let n = a.cols();
    if n != n_target {
        return Err(DapcError::Shape(format!(
            "native engine expects n_target == n ({n_target} != {n})"
        )));
    }
    match kind {
        InitKind::Qr => {
            // Paper eqs. (1)-(4): A = Q1 R, P = I - Q1^T Q1; the QR
            // factors are retained for per-RHS seeding.
            let f = qr::householder_qr_tiered(a, pool, tier);
            let qtq = blas::gemm_tn(&f.q1, &f.q1);
            let mut p = Matrix::eye(n);
            for i in 0..n {
                for j in 0..n {
                    p[(i, j)] -= qtq[(i, j)];
                }
            }
            Ok(retained(p, SeedFactors::Qr(f)))
        }
        InitKind::Classical => {
            // G^{-1} and P = I - G^{-1} G (numeric), in f64 like the
            // paper's NumPy baseline — the normal equations square
            // kappa(A), which in f32 makes the projector noise large
            // enough to diverge (DESIGN.md §1).
            let (ginv, p) = inverse::classical_factorize_f64(a)?;
            Ok(retained(p, SeedFactors::Classical { ginv }))
        }
        InitKind::Fat => {
            // A^T = Q R; P = I - Q Q^T; Q and R^T are retained.
            let at = a.transpose();
            let f = qr::householder_qr_tiered(&at, pool, tier);
            let rt = f.r.transpose();
            let q1t = f.q1.transpose();
            let mut qqt = Matrix::zeros(f.q1.rows(), f.q1.rows());
            // explicit-tier gemm so a per-solve override reaches the
            // projector build (Auto still shape-dispatches thin blocks)
            blas::gemm_into_on(
                simd::active(),
                tier,
                blas::GemmPath::Auto,
                &f.q1,
                &q1t,
                &mut qqt,
            );
            let mut p = Matrix::eye(n);
            for i in 0..n {
                for j in 0..n {
                    p[(i, j)] -= qqt[(i, j)];
                }
            }
            Ok(retained(p, SeedFactors::Fat { q1: f.q1, rt }))
        }
    }
}

/// Bytes of RHS-independent state one registered partition keeps
/// resident for warm serving: the densified (l x n) f32 block (read by
/// classical re-seeding and retained by every session), the (n x n) f32
/// projector, its prepacked A-panels ([`blas::packed_a_len`]`(n, n)`
/// f32 — the pack-once memory cost of the packed epoch path), and the
/// [`SeedFactors`] variant the [`InitKind`] retains (QR: l*n + n*n f32;
/// classical: n*n f64; fat: n*l + l*l f32).  Pure shape arithmetic —
/// [`crate::service::ServiceStats`] and `dapc kernels` report it
/// without touching the retained buffers.
pub fn resident_partition_bytes(kind: InitKind, l: usize, n: usize) -> u64 {
    let f32b = std::mem::size_of::<f32>() as u64;
    let block = (l * n) as u64 * f32b;
    let projector = (n * n) as u64 * f32b;
    let panels = blas::packed_a_len(n, n) as u64 * f32b;
    let seed = match kind {
        InitKind::Qr => (l * n + n * n) as u64 * f32b,
        InitKind::Classical => (n * n) as u64 * std::mem::size_of::<f64>() as u64,
        InitKind::Fat => (n * l + l * l) as u64 * f32b,
    };
    block + projector + panels + seed
}

/// Engine-agnostic operations used by the solvers and the coordinator.
pub trait ComputeEngine {
    /// Initialize one partition (dense block `a`, rhs `b`).
    ///
    /// `n_target` is the solution dimension the consensus loop will run at
    /// (engines that pad to shape buckets return padded outputs of exactly
    /// this width).
    fn init(
        &self,
        kind: InitKind,
        a: &Matrix,
        b: &[f32],
        n_target: usize,
    ) -> Result<WorkerInit>;

    /// The RHS-independent half of [`Self::init`]: factorize one
    /// partition and return the retained state a warm solver session
    /// re-seeds from.  Engines whose init is an opaque fused artifact
    /// (XLA) keep the default and report that sessions are unsupported.
    fn factorize(
        &self,
        _kind: InitKind,
        _a: &Matrix,
        _n_target: usize,
    ) -> Result<WorkerFactorization> {
        Err(DapcError::Artifact(format!(
            "engine {:?} does not retain factorizations; warm solver \
             sessions need the native or parallel engine",
            self.name()
        )))
    }

    /// [`Self::factorize`] over every partition of a session
    /// registration.  The blocks arrive densified — sessions retain them
    /// for seeding anyway, so (unlike [`Self::init_all`]) lazy
    /// densification would not bound peak memory.  Cold registration is
    /// embarrassingly parallel across partitions; pooled engines
    /// override.
    fn factorize_all(
        &self,
        kind: InitKind,
        blocks: &[Matrix],
        n_target: usize,
    ) -> Result<Vec<WorkerFactorization>> {
        blocks
            .iter()
            .map(|a| self.factorize(kind, a, n_target))
            .collect()
    }

    /// The per-RHS half of [`Self::init`]: seed `x_j(0)` for a fresh `b`
    /// through a retained factorization — bit-identical to the matching
    /// cold init, at O(l n + n^2) instead of O(l n^2).  `a` is the same
    /// block the factorization was built from (the classical path reads
    /// it for `A^T b`).
    fn seed(
        &self,
        _seed: &SeedFactors,
        _a: &Matrix,
        _b: &[f32],
    ) -> Result<Vec<f32>> {
        Err(DapcError::Artifact(format!(
            "engine {:?} does not retain factorizations; warm solver \
             sessions need the native or parallel engine",
            self.name()
        )))
    }

    /// Eq. (6) for one partition: `x + gamma * P (xbar - x)`.
    fn update(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<f32>>;

    /// Eq. (7): `eta * mean_j x_j + (1 - eta) * xbar`.
    fn average(&self, xs: &[Vec<f32>], xbar: &[f32], eta: f32) -> Result<Vec<f32>>;

    /// One fused epoch over all partitions; default = update-all + average.
    fn round(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let mut new_xs = Vec::with_capacity(xs.len());
        for (x, p) in xs.iter().zip(ps) {
            new_xs.push(self.update(x, xbar, p, gamma)?);
        }
        let new_xbar = self.average(&new_xs, xbar, eta)?;
        Ok((new_xs, new_xbar))
    }

    /// Eq. (6) into caller-provided buffers (`scratch` and `out` of
    /// length `x.len()`), allocating nothing.  Default copies through
    /// [`Self::update`]; allocation-free engines override.
    fn update_into(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
        scratch: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        let v = self.update(x, xbar, p, gamma)?;
        out.copy_from_slice(&v);
        let _ = scratch;
        Ok(())
    }

    /// Eq. (7) into caller-provided buffers (`acc` of length >= n, `out`
    /// of length n).  Default copies through [`Self::average`];
    /// allocation-free engines override.
    fn average_into(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        eta: f32,
        acc: &mut [f64],
        out: &mut [f32],
    ) -> Result<()> {
        let v = self.average(xs, xbar, eta)?;
        out.copy_from_slice(&v);
        let _ = acc;
        Ok(())
    }

    /// One fused epoch written into caller-provided buffers — the
    /// steady-state path [`crate::solver::DapcSolver`] iterates, so a
    /// warmed workspace makes the epoch loop allocation-free on engines
    /// that override this.  The default delegates to [`Self::round`]
    /// (preserving engine-specific fused paths, e.g. the XLA `round_*`
    /// artifacts) and moves the results into the output buffers.
    fn round_into(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
        ws: &mut RoundWorkspace,
        out_xs: &mut [Vec<f32>],
        out_xbar: &mut [f32],
    ) -> Result<()> {
        let (new_xs, new_xbar) = self.round(xs, xbar, ps, gamma, eta)?;
        for (o, v) in out_xs.iter_mut().zip(new_xs) {
            *o = v;
        }
        out_xbar.copy_from_slice(&new_xbar);
        let _ = ws;
        Ok(())
    }

    /// Eq. (6) over the k columns of a batched session solve for one
    /// partition (allocating variant, used by cluster workers).  Runs the
    /// shared column-blocked kernel: per column bit-identical to
    /// [`Self::update`], with the projector row widened once per batch.
    fn update_batch(
        &self,
        xs: &[Vec<f32>],
        xbars: &[Vec<f32>],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<Vec<f32>>> {
        if xs.len() != xbars.len() {
            return Err(DapcError::Shape(format!(
                "update_batch got {} estimates for {} averages",
                xs.len(),
                xbars.len()
            )));
        }
        let n = p.rows();
        for (x, xbar) in xs.iter().zip(xbars) {
            check_update_shapes(x, xbar, p, n, n)?;
        }
        let k = xs.len();
        let mut scratch = vec![vec![0.0f32; n]; k];
        let mut out = vec![vec![0.0f32; n]; k];
        update_batch_kernel(xs, xbars, p, gamma, &mut scratch, &mut out);
        Ok(out)
    }

    /// [`Self::update_batch`] through the prepacked projector panels
    /// retained in a [`WorkerFactorization`]: the k consensus directions
    /// are packed into B-panels and swept by the wide microkernel at
    /// tier-0, which is bit-identical to the row-dot kernel per element
    /// — so this is [`Self::update_batch`] exactly, minus the per-epoch
    /// widening/matrix traffic.  Cluster workers route their registered
    /// sessions through this.
    fn update_batch_packed(
        &self,
        xs: &[Vec<f32>],
        xbars: &[Vec<f32>],
        panels: &blas::PrepackedPanels,
        gamma: f32,
    ) -> Result<Vec<Vec<f32>>> {
        let (k, n) = check_update_batch_packed_shapes(xs, xbars, panels)?;
        if k == 0 {
            return Ok(Vec::new());
        }
        if n == 0 {
            return Ok(vec![Vec::new(); k]);
        }
        let mut bpack = vec![0.0f32; blas::packed_b_len(n, k)];
        pack_batch_diffs(xs, xbars, n, &mut bpack);
        let mut cbuf = vec![0.0f32; n * k];
        blas::packed_gemm_prepacked_into(
            simd::active(),
            KernelTier::Deterministic,
            panels,
            0,
            n,
            k,
            &bpack,
            &mut cbuf,
            k,
            1,
        );
        let mut out = vec![vec![0.0f32; n]; k];
        scale_batch_from_cbuf(xs, &cbuf, gamma, k, &mut out);
        Ok(out)
    }

    /// One fused epoch over all partitions AND all k RHS columns of a
    /// batched session solve: eq. (6) per (partition, column) through the
    /// column-blocked batched kernel, then eq. (7) independently per
    /// column.  `xs`/`out_xs` are indexed `[partition][column]`,
    /// `xbars`/`out_xbars` `[column]`.  Column for column this performs
    /// exactly the arithmetic of [`Self::round_into`], so batched solves
    /// stay bit-identical to sequential ones on every engine.
    fn round_batch_into(
        &self,
        xs: &[Vec<Vec<f32>>],
        xbars: &[Vec<f32>],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
        ws: &mut RoundWorkspace,
        out_xs: &mut [Vec<Vec<f32>>],
        out_xbars: &mut [Vec<f32>],
    ) -> Result<()> {
        let (j, k, n) =
            check_round_batch_shapes(xs, xbars, ps, out_xs, out_xbars)?;
        ws.ensure_batch(j, k, n);
        for (i, (x, out)) in xs.iter().zip(out_xs.iter_mut()).enumerate() {
            update_batch_kernel(
                x,
                xbars,
                &ps[i],
                gamma,
                &mut ws.scratch[i * k..(i + 1) * k],
                out,
            );
        }
        let mut cols: Vec<&[f32]> = Vec::with_capacity(j);
        for (c, (xbar, out_xbar)) in
            xbars.iter().zip(out_xbars.iter_mut()).enumerate()
        {
            cols.clear();
            cols.extend(out_xs.iter().map(|xj| xj[c].as_slice()));
            average_chunk_kernel(&cols, xbar, eta, 0, &mut ws.acc[..n], out_xbar);
        }
        Ok(())
    }

    /// [`Self::round_batch_into`] through prepacked projector panels:
    /// per partition the k consensus directions are packed into B-panel
    /// layout ([`pack_batch_diffs`]), swept by the wide microkernel at
    /// tier-0 against the A-panels retained at factorization time, and
    /// scattered back with the eq. (6) relaxation; eq. (7) then averages
    /// per column exactly as the row-dot path does.  Every output bit
    /// matches [`Self::round_batch_into`] on the same inputs — the wide
    /// microkernel's per-element accumulation order is the row-dot
    /// order — so engines route warm sessions here purely for speed.
    /// The epoch sweep is pinned to tier-0 regardless of the engine's
    /// factorization tier: consensus iterates stay bit-identical across
    /// kernel-tier configurations (only factorizations may differ).
    #[allow(clippy::too_many_arguments)]
    fn round_batch_packed_into(
        &self,
        xs: &[Vec<Vec<f32>>],
        xbars: &[Vec<f32>],
        ps: &[Matrix],
        panels: &[blas::PrepackedPanels],
        gamma: f32,
        eta: f32,
        ws: &mut RoundWorkspace,
        out_xs: &mut [Vec<Vec<f32>>],
        out_xbars: &mut [Vec<f32>],
    ) -> Result<()> {
        let (j, k, n) =
            check_round_batch_shapes(xs, xbars, ps, out_xs, out_xbars)?;
        check_prepacked_panels(panels, j, n)?;
        if n == 0 {
            return Ok(());
        }
        ws.ensure_packed(j, k, n);
        for (i, (x, out)) in xs.iter().zip(out_xs.iter_mut()).enumerate() {
            pack_batch_diffs(x, xbars, n, &mut ws.bpack[i]);
            blas::packed_gemm_prepacked_into(
                simd::active(),
                KernelTier::Deterministic,
                &panels[i],
                0,
                n,
                k,
                &ws.bpack[i],
                &mut ws.cbuf[i],
                k,
                1,
            );
            scale_batch_from_cbuf(x, &ws.cbuf[i], gamma, k, out);
        }
        let mut cols: Vec<&[f32]> = Vec::with_capacity(j);
        for (c, (xbar, out_xbar)) in
            xbars.iter().zip(out_xbars.iter_mut()).enumerate()
        {
            cols.clear();
            cols.extend(out_xs.iter().map(|xj| xj[c].as_slice()));
            average_chunk_kernel(&cols, xbar, eta, 0, &mut ws.acc[..n], out_xbar);
        }
        Ok(())
    }

    /// Initialize every partition (Algorithm 1 steps 2-3 across all J
    /// blocks).  `extract(i)` densifies block `i` on demand, so the
    /// serial default holds only ONE dense block at a time (same peak
    /// memory as extracting inline); engines with a thread pool override
    /// to extract + factorize partitions concurrently — init is
    /// embarrassingly parallel across partitions.
    fn init_all(
        &self,
        kind: InitKind,
        j: usize,
        extract: &(dyn Fn(usize) -> (Matrix, Vec<f32>) + Sync),
        n_target: usize,
    ) -> Result<Vec<WorkerInit>> {
        (0..j)
            .map(|i| {
                let (a, b) = extract(i);
                self.init(kind, &a, &b, n_target)
            })
            .collect()
    }

    /// T fused epochs in one call when the engine supports it (the XLA
    /// engine runs the whole loop inside a single executable); `None`
    /// means the caller should iterate [`Self::round`].
    fn solve_loop(
        &self,
        _xs: &[Vec<f32>],
        _xbar: &[f32],
        _ps: &[Matrix],
        _gamma: f32,
        _eta: f32,
        _epochs: usize,
    ) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
        Ok(None)
    }

    /// DGD worker gradient `A^T (A x - b)`.
    fn dgd_grad(&self, a: &Matrix, x: &[f32], b: &[f32]) -> Result<Vec<f32>>;

    /// [`Self::dgd_grad`] into caller buffers: `ax_scratch` of length
    /// `a.rows()`, `out` of length `a.cols()`.  Default copies through
    /// `dgd_grad`; allocation-free engines override.
    fn dgd_grad_into(
        &self,
        a: &Matrix,
        x: &[f32],
        b: &[f32],
        ax_scratch: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        let g = self.dgd_grad(a, x, b)?;
        out.copy_from_slice(&g);
        let _ = ax_scratch;
        Ok(())
    }

    /// The (l_pad, n_pad) bucket this engine needs for a block of shape
    /// (rows, n), or `None` when exact shapes are fine.
    fn init_bucket(
        &self,
        _kind: InitKind,
        _rows: usize,
        _n: usize,
    ) -> Result<Option<(usize, usize)>> {
        Ok(None)
    }

    /// Engine label for reports.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Native engine
// ---------------------------------------------------------------------------

/// Pure-Rust engine over `crate::linalg` — the correctness reference.
///
/// Carries the [`KernelTier`] its factorizations run at: [`Self::new`]
/// reads the process default (`DAPC_KERNEL_TIER`), [`Self::with_tier`]
/// pins one explicitly (the CLI routes
/// [`crate::solver::SolveOptions::kernel_tier`] through this).  The
/// tier only touches the f32 gemm microkernel — consensus iterates go
/// through `dot`/`dot_wide`/`axpy`, which are tier-independent — so two
/// engines at different tiers differ (at most) in their factorizations.
#[derive(Debug, Clone)]
pub struct NativeEngine {
    tier: KernelTier,
}

impl NativeEngine {
    /// Engine at the process-default kernel tier.
    pub fn new() -> Self {
        Self { tier: simd::active_tier() }
    }

    /// Engine pinned to an explicit kernel tier.
    pub fn with_tier(tier: KernelTier) -> Self {
        Self { tier }
    }

    /// The kernel tier this engine factorizes at.
    pub fn tier(&self) -> KernelTier {
        self.tier
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl ComputeEngine for NativeEngine {
    fn init(
        &self,
        kind: InitKind,
        a: &Matrix,
        b: &[f32],
        n_target: usize,
    ) -> Result<WorkerInit> {
        // factorize + seed IS the cold init: warm sessions re-running
        // `seed` on a retained factorization are bit-identical to a cold
        // solve by construction, not by coincidence.
        let fac = self.factorize(kind, a, n_target)?;
        let x0 = self.seed(&fac.seed, a, b)?;
        Ok(WorkerInit { x0, projector: fac.projector })
    }

    fn factorize(
        &self,
        kind: InitKind,
        a: &Matrix,
        n_target: usize,
    ) -> Result<WorkerFactorization> {
        // the shared panel-blocked kernel, serial: this engine has no
        // threads to offer the trailing updates
        factorize_kernel(kind, a, n_target, None, self.tier)
    }

    fn seed(
        &self,
        seed: &SeedFactors,
        a: &Matrix,
        b: &[f32],
    ) -> Result<Vec<f32>> {
        match seed {
            SeedFactors::Qr(f) => {
                if b.len() != f.q1.rows() {
                    return Err(DapcError::Shape(format!(
                        "seed rhs length {} != block rows {}",
                        b.len(),
                        f.q1.rows()
                    )));
                }
                // x0 = R^{-1} Q1^T b (eqs. (2)-(3))
                let c = qr::qt_mul(f, b);
                Ok(triangular::back_substitute(&f.r, &c))
            }
            SeedFactors::Classical { ginv } => {
                inverse::classical_seed_f64(a, ginv, b)
            }
            SeedFactors::Fat { q1, rt } => {
                if b.len() != rt.rows() {
                    return Err(DapcError::Shape(format!(
                        "seed rhs length {} != block rows {}",
                        b.len(),
                        rt.rows()
                    )));
                }
                // x0 = Q (R^T)^{-1} b
                let c = triangular::forward_substitute(rt, b);
                let mut x0 = vec![0.0f32; q1.rows()];
                blas::gemv(q1, &c, &mut x0);
                Ok(x0)
            }
        }
    }

    fn update(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let n = x.len();
        let mut scratch = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        self.update_into(x, xbar, p, gamma, &mut scratch, &mut out)?;
        Ok(out)
    }

    fn update_into(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
        scratch: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        check_update_shapes(x, xbar, p, scratch.len(), out.len())?;
        update_kernel(x, xbar, p, gamma, scratch, out);
        Ok(())
    }

    fn average(&self, xs: &[Vec<f32>], xbar: &[f32], eta: f32) -> Result<Vec<f32>> {
        let n = xbar.len();
        let mut acc = vec![0.0f64; n];
        let mut out = vec![0.0f32; n];
        self.average_into(xs, xbar, eta, &mut acc, &mut out)?;
        Ok(out)
    }

    fn average_into(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        eta: f32,
        acc: &mut [f64],
        out: &mut [f32],
    ) -> Result<()> {
        let n = xbar.len();
        check_average_shapes(xs, n, acc.len(), out.len())?;
        average_chunk_kernel(xs, xbar, eta, 0, &mut acc[..n], out);
        Ok(())
    }

    fn round_into(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
        ws: &mut RoundWorkspace,
        out_xs: &mut [Vec<f32>],
        out_xbar: &mut [f32],
    ) -> Result<()> {
        let j = xs.len();
        check_round_shapes(xs, ps, out_xs, xbar.len())?;
        ws.ensure(j, xbar.len());
        for i in 0..j {
            self.update_into(
                &xs[i],
                xbar,
                &ps[i],
                gamma,
                &mut ws.scratch[i],
                &mut out_xs[i],
            )?;
        }
        self.average_into(&*out_xs, xbar, eta, &mut ws.acc, out_xbar)
    }

    fn round(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let mut out_xs: Vec<Vec<f32>> =
            xs.iter().map(|x| vec![0.0f32; x.len()]).collect();
        let mut out_xbar = vec![0.0f32; xbar.len()];
        let mut ws = RoundWorkspace::for_shape(xs.len(), xbar.len());
        self.round_into(
            xs,
            xbar,
            ps,
            gamma,
            eta,
            &mut ws,
            &mut out_xs,
            &mut out_xbar,
        )?;
        Ok((out_xs, out_xbar))
    }

    fn dgd_grad(&self, a: &Matrix, x: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let mut ax = vec![0.0f32; a.rows()];
        let mut g = vec![0.0f32; a.cols()];
        self.dgd_grad_into(a, x, b, &mut ax, &mut g)?;
        Ok(g)
    }

    fn dgd_grad_into(
        &self,
        a: &Matrix,
        x: &[f32],
        b: &[f32],
        ax_scratch: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        check_dgd_shapes(a, x, b, ax_scratch.len(), out.len())?;
        blas::gemv(a, x, ax_scratch);
        for (axi, bi) in ax_scratch.iter_mut().zip(b) {
            *axi -= bi;
        }
        blas::gemv_t(a, ax_scratch, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Shared shape validation for the update paths (native + parallel).
pub(crate) fn check_update_shapes(
    x: &[f32],
    xbar: &[f32],
    p: &Matrix,
    scratch_len: usize,
    out_len: usize,
) -> Result<()> {
    let n = x.len();
    if xbar.len() != n || scratch_len != n || out_len != n {
        return Err(DapcError::Shape(format!(
            "update_into buffer lengths ({}, {scratch_len}, {out_len}) \
             != n = {n}",
            xbar.len()
        )));
    }
    if p.shape() != (n, n) {
        return Err(DapcError::Shape(format!(
            "projector shape {:?} != ({n}, {n})",
            p.shape()
        )));
    }
    Ok(())
}

/// Shared shape validation for the average paths (native + parallel).
pub(crate) fn check_average_shapes<S: AsRef<[f32]>>(
    xs: &[S],
    n: usize,
    acc_len: usize,
    out_len: usize,
) -> Result<()> {
    if xs.is_empty() {
        return Err(DapcError::Shape("average over zero partitions".into()));
    }
    if acc_len < n || out_len != n {
        return Err(DapcError::Shape(format!(
            "average_into buffers (acc {acc_len}, out {out_len}) \
             incompatible with n = {n}"
        )));
    }
    if let Some(bad) = xs.iter().find(|x| x.as_ref().len() < n) {
        return Err(DapcError::Shape(format!(
            "estimate length {} < n = {n}",
            bad.as_ref().len()
        )));
    }
    Ok(())
}

/// Shared shape validation for the batched round paths; returns
/// `(J, k, n)` on success.
pub(crate) fn check_round_batch_shapes(
    xs: &[Vec<Vec<f32>>],
    xbars: &[Vec<f32>],
    ps: &[Matrix],
    out_xs: &[Vec<Vec<f32>>],
    out_xbars: &[Vec<f32>],
) -> Result<(usize, usize, usize)> {
    let j = xs.len();
    if j == 0 {
        return Err(DapcError::Shape(
            "batched round over zero partitions".into(),
        ));
    }
    let k = xbars.len();
    if k == 0 {
        return Err(DapcError::Shape(
            "batched round over zero rhs columns".into(),
        ));
    }
    let n = xbars[0].len();
    if ps.len() != j || out_xs.len() != j {
        return Err(DapcError::Shape(format!(
            "batched round over {j} partitions got {} projectors / {} \
             outputs",
            ps.len(),
            out_xs.len()
        )));
    }
    if out_xbars.len() != k {
        return Err(DapcError::Shape(format!(
            "batched round over {k} columns got {} output averages",
            out_xbars.len()
        )));
    }
    for v in xbars.iter().chain(out_xbars.iter()) {
        if v.len() != n {
            return Err(DapcError::Shape(format!(
                "batched round average length {} != n = {n}",
                v.len()
            )));
        }
    }
    for (x, o) in xs.iter().zip(out_xs) {
        if x.len() != k || o.len() != k {
            return Err(DapcError::Shape(format!(
                "batched round estimate widths ({}, {}) != k = {k}",
                x.len(),
                o.len()
            )));
        }
        for col in x.iter().chain(o.iter()) {
            if col.len() != n {
                return Err(DapcError::Shape(format!(
                    "batched round estimate length {} != n = {n}",
                    col.len()
                )));
            }
        }
    }
    for p in ps {
        if p.shape() != (n, n) {
            return Err(DapcError::Shape(format!(
                "projector shape {:?} != ({n}, {n})",
                p.shape()
            )));
        }
    }
    Ok((j, k, n))
}

/// Shared shape validation for the prepacked batched update paths
/// (native + parallel); returns `(k, n)` on success.
pub(crate) fn check_update_batch_packed_shapes(
    xs: &[Vec<f32>],
    xbars: &[Vec<f32>],
    panels: &blas::PrepackedPanels,
) -> Result<(usize, usize)> {
    if xs.len() != xbars.len() {
        return Err(DapcError::Shape(format!(
            "update_batch_packed got {} estimates for {} averages",
            xs.len(),
            xbars.len()
        )));
    }
    let n = panels.m();
    if panels.k() != n {
        return Err(DapcError::Shape(format!(
            "prepacked projector panels are {}x{}, expected square",
            panels.m(),
            panels.k()
        )));
    }
    if let Some(bad) = xs.iter().chain(xbars).find(|v| v.len() != n) {
        return Err(DapcError::Shape(format!(
            "update_batch_packed column length {} != n = {n}",
            bad.len()
        )));
    }
    Ok((xs.len(), n))
}

/// Shared shape validation for the prepacked batched round paths
/// (native + parallel): one square (n x n) panel set per partition.
pub(crate) fn check_prepacked_panels(
    panels: &[blas::PrepackedPanels],
    j: usize,
    n: usize,
) -> Result<()> {
    if panels.len() != j {
        return Err(DapcError::Shape(format!(
            "prepacked round over {j} partitions got {} panel sets",
            panels.len()
        )));
    }
    if let Some(bad) = panels.iter().find(|p| p.m() != n || p.k() != n) {
        return Err(DapcError::Shape(format!(
            "prepacked panels pack a {}x{} projector, expected ({n}, {n})",
            bad.m(),
            bad.k()
        )));
    }
    Ok(())
}

/// Shared shape validation for the round paths (native + parallel).
pub(crate) fn check_round_shapes(
    xs: &[Vec<f32>],
    ps: &[Matrix],
    out_xs: &[Vec<f32>],
    n: usize,
) -> Result<()> {
    let j = xs.len();
    if ps.len() != j || out_xs.len() != j {
        return Err(DapcError::Shape(format!(
            "round over {j} partitions got {} projectors / {} outputs",
            ps.len(),
            out_xs.len()
        )));
    }
    for (x, o) in xs.iter().zip(out_xs) {
        if x.len() != n || o.len() != n {
            return Err(DapcError::Shape(format!(
                "round estimate/output lengths ({}, {}) != n = {n}",
                x.len(),
                o.len()
            )));
        }
    }
    for p in ps {
        if p.shape() != (n, n) {
            return Err(DapcError::Shape(format!(
                "projector shape {:?} != ({n}, {n})",
                p.shape()
            )));
        }
    }
    Ok(())
}

/// Shared shape validation for the DGD gradient paths.
pub(crate) fn check_dgd_shapes(
    a: &Matrix,
    x: &[f32],
    b: &[f32],
    ax_len: usize,
    out_len: usize,
) -> Result<()> {
    let (l, n) = a.shape();
    if x.len() != n || b.len() != l || ax_len != l || out_len != n {
        return Err(DapcError::Shape(format!(
            "dgd_grad_into shapes (x {}, b {}, ax {ax_len}, out {out_len}) \
             incompatible with A {l}x{n}",
            x.len(),
            b.len()
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// XLA engine
// ---------------------------------------------------------------------------

/// Engine executing AOT HLO artifacts through the PJRT runtime (the
/// Layer-1/2 production path).  Blocks are padded to manifest buckets;
/// padding is exact (DESIGN.md §3).
#[derive(Clone)]
pub struct XlaEngine {
    exec: XlaExecutor,
    /// Use the per-epoch fused `round_*` artifacts when available.
    pub fused_rounds: bool,
    /// Use the whole-loop `solve_*` artifacts when available.
    pub fused_loop: bool,
}

impl XlaEngine {
    pub fn new(exec: XlaExecutor) -> Self {
        Self { exec, fused_rounds: true, fused_loop: false }
    }

    pub fn executor(&self) -> &XlaExecutor {
        &self.exec
    }

    fn n_of(&self, xbar: &[f32]) -> usize {
        xbar.len()
    }
}

impl ComputeEngine for XlaEngine {
    fn init(
        &self,
        kind: InitKind,
        a: &Matrix,
        b: &[f32],
        n_target: usize,
    ) -> Result<WorkerInit> {
        let akind = kind.artifact_kind();
        // pad to the bucket whose n equals n_target
        let buckets = self.exec.init_buckets(akind)?;
        let (rows, n) = a.shape();
        let (l_pad, n_pad) = buckets
            .iter()
            .copied()
            .filter(|&(l, np)| np == n_target && l >= rows + (np - n))
            .min_by_key(|&(l, _)| l)
            .ok_or_else(|| {
                DapcError::Artifact(format!(
                    "no {akind} artifact with n={n_target} fitting {rows}x{n}; \
                     available buckets: {buckets:?} (rebuild with \
                     `make artifacts` and a matching shape manifest)"
                ))
            })?;
        let blk = pad_to_bucket(a, b, l_pad, n_pad)?;
        let name = format!("{akind}_l{l_pad}_n{n_pad}");
        let out = self.exec.execute(
            &name,
            vec![Tensor::from_matrix(&blk.a), Tensor::vec1(blk.b.clone())],
        )?;
        let [x0, p]: [Tensor; 2] = out.try_into().map_err(|_| {
            DapcError::Artifact(format!("{name}: expected 2 outputs"))
        })?;
        Ok(WorkerInit { x0: x0.into_f32()?, projector: p.to_matrix()? })
    }

    fn update(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let n = self.n_of(xbar);
        let name = format!("update_n{n}");
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::vec1(x.to_vec()),
                Tensor::vec1(xbar.to_vec()),
                Tensor::from_matrix(p),
                Tensor::scalar_f32(gamma),
            ],
        )?;
        out.into_iter()
            .next()
            .ok_or_else(|| DapcError::Artifact(format!("{name}: no output")))?
            .into_f32()
    }

    fn average(&self, xs: &[Vec<f32>], xbar: &[f32], eta: f32) -> Result<Vec<f32>> {
        let (j, n) = (xs.len(), self.n_of(xbar));
        let name = format!("average_j{j}_n{n}");
        if !self.exec.has_artifact(&name)? {
            // eq. (7) is a leader-side O(Jn) reduction; when no artifact
            // was AOT-built for this J we compute it natively — exactly
            // what the distributed leader does on its side of the wire.
            return NativeEngine::new().average(xs, xbar, eta);
        }
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_rows(xs)?,
                Tensor::vec1(xbar.to_vec()),
                Tensor::scalar_f32(eta),
            ],
        )?;
        out.into_iter()
            .next()
            .ok_or_else(|| DapcError::Artifact(format!("{name}: no output")))?
            .into_f32()
    }

    fn round(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let (j, n) = (xs.len(), self.n_of(xbar));
        let name = format!("round_j{j}_n{n}");
        if !self.fused_rounds || !self.exec.has_artifact(&name)? {
            // fall back to per-op path
            let mut new_xs = Vec::with_capacity(xs.len());
            for (x, p) in xs.iter().zip(ps) {
                new_xs.push(self.update(x, xbar, p, gamma)?);
            }
            let new_xbar = self.average(&new_xs, xbar, eta)?;
            return Ok((new_xs, new_xbar));
        }
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_rows(xs)?,
                Tensor::vec1(xbar.to_vec()),
                Tensor::from_matrices(ps)?,
                Tensor::scalar_f32(gamma),
                Tensor::scalar_f32(eta),
            ],
        )?;
        let [xs_t, xbar_t]: [Tensor; 2] = out.try_into().map_err(|_| {
            DapcError::Artifact(format!("{name}: expected 2 outputs"))
        })?;
        Ok((xs_t.into_rows()?, xbar_t.into_f32()?))
    }

    fn solve_loop(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
        epochs: usize,
    ) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
        let (j, n) = (xs.len(), self.n_of(xbar));
        let name = format!("solve_j{j}_n{n}");
        if !self.fused_loop || !self.exec.has_artifact(&name)? {
            return Ok(None);
        }
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_rows(xs)?,
                Tensor::vec1(xbar.to_vec()),
                Tensor::from_matrices(ps)?,
                Tensor::scalar_f32(gamma),
                Tensor::scalar_f32(eta),
                Tensor::I32Scalar(epochs as i32),
            ],
        )?;
        let [xs_t, xbar_t]: [Tensor; 2] = out.try_into().map_err(|_| {
            DapcError::Artifact(format!("{name}: expected 2 outputs"))
        })?;
        Ok(Some((xs_t.into_rows()?, xbar_t.into_f32()?)))
    }

    fn dgd_grad(&self, a: &Matrix, x: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (rows, n) = a.shape();
        // pad to the nearest dgd_grad bucket: zero rows contribute nothing
        // to A^T (A x - b) (b padded with zeros), identity-extended columns
        // produce zero gradient entries which we truncate below.
        let buckets = self.exec.init_buckets("dgd_grad")?;
        let (l_pad, n_pad) =
            crate::partition::bucket::choose_bucket(rows, n, &buckets)
                .ok_or_else(|| {
                    DapcError::Artifact(format!(
                        "no dgd_grad artifact fits {rows}x{n}; buckets: \
                         {buckets:?}"
                    ))
                })?;
        let blk = pad_to_bucket(a, b, l_pad, n_pad)?;
        let mut x_pad = x.to_vec();
        x_pad.resize(n_pad, 0.0);
        let name = format!("dgd_grad_l{l_pad}_n{n_pad}");
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_matrix(&blk.a),
                Tensor::vec1(x_pad),
                Tensor::vec1(blk.b.clone()),
            ],
        )?;
        let mut g = out
            .into_iter()
            .next()
            .ok_or_else(|| DapcError::Artifact(format!("{name}: no output")))?
            .into_f32()?;
        g.truncate(n);
        Ok(g)
    }

    fn init_bucket(
        &self,
        kind: InitKind,
        rows: usize,
        n: usize,
    ) -> Result<Option<(usize, usize)>> {
        let buckets = self.exec.init_buckets(kind.artifact_kind())?;
        Ok(crate::partition::bucket::choose_bucket(rows, n, &buckets))
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::bucket;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    fn consistent(l: usize, n: usize, seed: u64) -> (Matrix, Vec<f32>, Vec<f32>) {
        let a = randm(l, n, seed);
        let mut g = seeded(seed + 1);
        let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; l];
        blas::gemv(&a, &x, &mut b);
        (a, b, x)
    }

    #[test]
    fn native_init_qr_solves() {
        let (a, b, x_true) = consistent(48, 16, 1);
        let e = NativeEngine::new();
        let init = e.init(InitKind::Qr, &a, &b, 16).unwrap();
        for i in 0..16 {
            assert!((init.x0[i] - x_true[i]).abs() < 1e-2, "i={i}");
        }
        // tall regime: projector is rounding noise
        assert!(crate::linalg::norms::max_abs(init.projector.as_slice()) < 1e-3);
    }

    #[test]
    fn native_init_classical_solves() {
        let (a, b, x_true) = consistent(48, 16, 2);
        let e = NativeEngine::new();
        let init = e.init(InitKind::Classical, &a, &b, 16).unwrap();
        for i in 0..16 {
            assert!((init.x0[i] - x_true[i]).abs() < 5e-2, "i={i}");
        }
    }

    #[test]
    fn native_init_fat_min_norm() {
        let (a, b, _) = consistent(8, 24, 3);
        let e = NativeEngine::new();
        let init = e.init(InitKind::Fat, &a, &b, 24).unwrap();
        // residual ~ 0
        let mut ax = vec![0.0f32; 8];
        blas::gemv(&a, &init.x0, &mut ax);
        for i in 0..8 {
            assert!((ax[i] - b[i]).abs() < 1e-3);
        }
        // projector idempotent with trace = n - l
        let pp = blas::gemm(&init.projector, &init.projector);
        assert!(pp.max_abs_diff(&init.projector) < 1e-3);
        let tr: f32 = (0..24).map(|i| init.projector[(i, i)]).sum();
        assert!((tr - 16.0).abs() < 1e-2);
    }

    #[test]
    fn native_update_and_average_semantics() {
        let e = NativeEngine::new();
        let x = vec![1.0f32, 2.0];
        let xbar = vec![3.0f32, 4.0];
        let p = Matrix::eye(2);
        // gamma 0.5, P = I: x + 0.5 (xbar - x) = midpoint
        let up = e.update(&x, &xbar, &p, 0.5).unwrap();
        assert_eq!(up, vec![2.0, 3.0]);
        // eta = 1: plain mean
        let avg = e
            .average(&[vec![0.0, 0.0], vec![2.0, 4.0]], &xbar, 1.0)
            .unwrap();
        assert_eq!(avg, vec![1.0, 2.0]);
        // eta = 0: keep xbar
        let keep = e
            .average(&[vec![9.0, 9.0]], &xbar, 0.0)
            .unwrap();
        assert_eq!(keep, xbar);
    }

    #[test]
    fn native_round_consistent_with_parts() {
        let e = NativeEngine::new();
        let mut g = seeded(5);
        let n = 12;
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let ps: Vec<Matrix> =
            (0..3).map(|i| randm(n, n, 40 + i)).collect();
        let (xs2, xbar2) = e.round(&xs, &xbar, &ps, 0.7, 0.4).unwrap();
        // manual
        let mut manual = Vec::new();
        for (x, p) in xs.iter().zip(&ps) {
            manual.push(e.update(x, &xbar, p, 0.7).unwrap());
        }
        let manual_avg = e.average(&manual, &xbar, 0.4).unwrap();
        assert_eq!(xs2, manual);
        assert_eq!(xbar2, manual_avg);
    }

    #[test]
    fn native_dgd_grad_zero_at_solution() {
        let (a, b, x_true) = consistent(20, 8, 7);
        let e = NativeEngine::new();
        let g = e.dgd_grad(&a, &x_true, &b).unwrap();
        assert!(crate::linalg::norms::max_abs(&g) < 1e-3);
    }

    #[test]
    fn bucket_helper_exposed() {
        // choose_bucket re-export sanity
        assert_eq!(
            bucket::choose_bucket(10, 4, &[(16, 4)]),
            Some((16, 4))
        );
    }

    #[test]
    fn into_variants_match_allocating_paths_exactly() {
        let e = NativeEngine::new();
        let mut g = seeded(77);
        let n = 19; // odd on purpose: exercises unaligned lengths
        let j = 3;
        let xs: Vec<Vec<f32>> = (0..j)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let ps: Vec<Matrix> = (0..j)
            .map(|i| randm(n, n, 400 + i as u64))
            .collect();

        // update_into == update
        let want = e.update(&xs[0], &xbar, &ps[0], 0.8).unwrap();
        let mut scratch = vec![0.0f32; n];
        let mut got = vec![0.0f32; n];
        e.update_into(&xs[0], &xbar, &ps[0], 0.8, &mut scratch, &mut got)
            .unwrap();
        assert_eq!(want, got);

        // average_into == average
        let want = e.average(&xs, &xbar, 0.7).unwrap();
        let mut acc = vec![0.0f64; n];
        let mut got = vec![0.0f32; n];
        e.average_into(&xs, &xbar, 0.7, &mut acc, &mut got).unwrap();
        assert_eq!(want, got);

        // round_into == round, workspace reused across epochs
        let mut ws = RoundWorkspace::for_shape(j, n);
        let mut out_xs: Vec<Vec<f32>> = vec![vec![0.0; n]; j];
        let mut out_xbar = vec![0.0f32; n];
        let (want_xs, want_xbar) = e.round(&xs, &xbar, &ps, 0.7, 0.4).unwrap();
        e.round_into(
            &xs, &xbar, &ps, 0.7, 0.4, &mut ws, &mut out_xs, &mut out_xbar,
        )
        .unwrap();
        assert_eq!(want_xs, out_xs);
        assert_eq!(want_xbar, out_xbar);

        // second epoch through the same workspace
        let (want_xs2, want_xbar2) =
            e.round(&out_xs, &out_xbar, &ps, 0.7, 0.4).unwrap();
        let mut out_xs2: Vec<Vec<f32>> = vec![vec![0.0; n]; j];
        let mut out_xbar2 = vec![0.0f32; n];
        e.round_into(
            &out_xs, &out_xbar, &ps, 0.7, 0.4, &mut ws, &mut out_xs2,
            &mut out_xbar2,
        )
        .unwrap();
        assert_eq!(want_xs2, out_xs2);
        assert_eq!(want_xbar2, out_xbar2);
    }

    #[test]
    fn dgd_grad_into_matches_and_validates() {
        let (a, b, x_true) = consistent(20, 8, 7);
        let e = NativeEngine::new();
        let want = e.dgd_grad(&a, &x_true, &b).unwrap();
        let mut ax = vec![0.0f32; 20];
        let mut got = vec![0.0f32; 8];
        e.dgd_grad_into(&a, &x_true, &b, &mut ax, &mut got).unwrap();
        assert_eq!(want, got);
        // bad buffer lengths are rejected, not UB
        let mut short = vec![0.0f32; 3];
        assert!(e
            .dgd_grad_into(&a, &x_true, &b, &mut ax, &mut short)
            .is_err());
    }

    #[test]
    fn init_all_matches_per_partition_init() {
        let e = NativeEngine::new();
        let blocks: Vec<(Matrix, Vec<f32>)> = (0..3)
            .map(|i| {
                let (a, b, _) = consistent(24, 8, 30 + i);
                (a, b)
            })
            .collect();
        let all = e
            .init_all(InitKind::Qr, 3, &|i| blocks[i].clone(), 8)
            .unwrap();
        assert_eq!(all.len(), 3);
        for (w, (a, b)) in all.iter().zip(&blocks) {
            let single = e.init(InitKind::Qr, a, b, 8).unwrap();
            assert_eq!(w.x0, single.x0);
        }
    }

    #[test]
    fn factorize_then_seed_bitwise_matches_cold_init() {
        let e = NativeEngine::new();
        // tall QR + classical, and a genuine fat block
        for (kind, l, n) in [
            (InitKind::Qr, 48usize, 16usize),
            (InitKind::Classical, 48, 16),
            (InitKind::Fat, 8, 24),
        ] {
            let (a, b, _) = consistent(l, n, 60 + l as u64);
            let cold = e.init(kind, &a, &b, n).unwrap();
            let fac = e.factorize(kind, &a, n).unwrap();
            assert_eq!(
                cold.projector.as_slice(),
                fac.projector.as_slice(),
                "{kind:?}"
            );
            // seeding the SAME factorization with several rhs must match
            // a cold init for each — the warm-session contract
            for seed_idx in 0..3u64 {
                let mut g = seeded(500 + seed_idx);
                let b2: Vec<f32> = (0..l).map(|_| g.normal_f32()).collect();
                let warm = e.seed(&fac.seed, &a, &b2).unwrap();
                let cold2 = e.init(kind, &a, &b2, n).unwrap();
                assert_eq!(warm, cold2.x0, "{kind:?} seed {seed_idx}");
            }
            // wrong rhs length is an error, not UB
            assert!(e.seed(&fac.seed, &a, &b[..l - 1]).is_err());
        }
    }

    #[test]
    fn factorize_all_matches_per_partition_factorize() {
        let e = NativeEngine::new();
        let blocks: Vec<Matrix> = (0..3)
            .map(|i| {
                let (a, _, _) = consistent(24, 8, 70 + i);
                a
            })
            .collect();
        let all = e.factorize_all(InitKind::Qr, &blocks, 8).unwrap();
        assert_eq!(all.len(), 3);
        for (fac, a) in all.iter().zip(&blocks) {
            let single = e.factorize(InitKind::Qr, a, 8).unwrap();
            assert_eq!(
                fac.projector.as_slice(),
                single.projector.as_slice()
            );
        }
        // the n_target check still guards every block
        assert!(e.factorize_all(InitKind::Qr, &blocks, 9).is_err());
    }

    #[test]
    fn update_batch_bitwise_matches_sequential_updates() {
        let e = NativeEngine::new();
        let mut g = seeded(88);
        let (n, k) = (23usize, 5usize);
        let p = randm(n, n, 888);
        let xs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbars: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let batch = e.update_batch(&xs, &xbars, &p, 0.8).unwrap();
        for c in 0..k {
            let single = e.update(&xs[c], &xbars[c], &p, 0.8).unwrap();
            assert_eq!(batch[c], single, "column {c}");
        }
        // mismatched widths rejected
        assert!(e.update_batch(&xs, &xbars[..k - 1], &p, 0.8).is_err());
    }

    #[test]
    fn round_batch_bitwise_matches_per_column_rounds() {
        let e = NativeEngine::new();
        let mut g = seeded(91);
        let (j, k, n) = (3usize, 4usize, 17usize);
        let ps: Vec<Matrix> = (0..j).map(|i| randm(n, n, 700 + i as u64)).collect();
        // xs[partition][column]
        let xs: Vec<Vec<Vec<f32>>> = (0..j)
            .map(|_| {
                (0..k)
                    .map(|_| (0..n).map(|_| g.normal_f32()).collect())
                    .collect()
            })
            .collect();
        let xbars: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();

        let mut ws = RoundWorkspace::default();
        let mut out_xs: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; n]; k]; j];
        let mut out_xbars: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
        e.round_batch_into(
            &xs, &xbars, &ps, 0.7, 0.6, &mut ws, &mut out_xs, &mut out_xbars,
        )
        .unwrap();

        for c in 0..k {
            // column c in isolation through the single-RHS round path
            let col_xs: Vec<Vec<f32>> =
                (0..j).map(|i| xs[i][c].clone()).collect();
            let (want_xs, want_xbar) =
                e.round(&col_xs, &xbars[c], &ps, 0.7, 0.6).unwrap();
            for i in 0..j {
                assert_eq!(out_xs[i][c], want_xs[i], "j={i} c={c}");
            }
            assert_eq!(out_xbars[c], want_xbar, "c={c}");
        }
    }

    #[test]
    fn round_batch_packed_bitwise_matches_row_dot() {
        let e = NativeEngine::new();
        // shapes crossing MR/NR panel boundaries and k < NR, k == 1
        for (j, k, n) in [
            (3usize, 4usize, 17usize),
            (2, 1, 8),
            (1, 3, 29),
            (2, 9, 23),
        ] {
            let mut g = seeded(9000 + (j * 100 + k * 10 + n) as u64);
            let ps: Vec<Matrix> =
                (0..j).map(|i| randm(n, n, 710 + i as u64)).collect();
            let panels: Vec<blas::PrepackedPanels> =
                ps.iter().map(blas::PrepackedPanels::from_matrix).collect();
            let xs: Vec<Vec<Vec<f32>>> = (0..j)
                .map(|_| {
                    (0..k)
                        .map(|_| (0..n).map(|_| g.normal_f32()).collect())
                        .collect()
                })
                .collect();
            let xbars: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| g.normal_f32()).collect())
                .collect();

            let mut ws = RoundWorkspace::default();
            let mut want_xs: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; n]; k]; j];
            let mut want_xbars: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
            e.round_batch_into(
                &xs,
                &xbars,
                &ps,
                0.7,
                0.6,
                &mut ws,
                &mut want_xs,
                &mut want_xbars,
            )
            .unwrap();

            let mut pws = RoundWorkspace::default();
            let mut got_xs: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; n]; k]; j];
            let mut got_xbars: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
            e.round_batch_packed_into(
                &xs,
                &xbars,
                &ps,
                &panels,
                0.7,
                0.6,
                &mut pws,
                &mut got_xs,
                &mut got_xbars,
            )
            .unwrap();

            assert_eq!(want_xs, got_xs, "j={j} k={k} n={n}");
            assert_eq!(want_xbars, got_xbars, "j={j} k={k} n={n}");
        }
    }

    #[test]
    fn update_batch_packed_bitwise_matches_update_batch() {
        let e = NativeEngine::new();
        let mut g = seeded(92);
        let (n, k) = (21usize, 5usize);
        let p = randm(n, n, 921);
        let panels = blas::PrepackedPanels::from_matrix(&p);
        let xs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbars: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let want = e.update_batch(&xs, &xbars, &p, 0.8).unwrap();
        let got = e.update_batch_packed(&xs, &xbars, &panels, 0.8).unwrap();
        assert_eq!(want, got);
        // mismatched widths and wrong column lengths are rejected
        assert!(e
            .update_batch_packed(&xs, &xbars[..k - 1], &panels, 0.8)
            .is_err());
        let short = vec![vec![0.0f32; n - 1]; k];
        assert!(e.update_batch_packed(&short, &xbars, &panels, 0.8).is_err());
    }

    #[test]
    fn packed_round_rejects_mismatched_panels() {
        let e = NativeEngine::new();
        let n = 6;
        let xs = vec![vec![vec![0.0f32; n]]];
        let xbars = vec![vec![0.0f32; n]];
        let ps = vec![Matrix::eye(n)];
        // panels packed from a projector of the WRONG shape
        let panels = vec![blas::PrepackedPanels::from_matrix(&Matrix::eye(5))];
        let mut ws = RoundWorkspace::default();
        let mut out_xs = vec![vec![vec![0.0f32; n]]];
        let mut out_xbars = vec![vec![0.0f32; n]];
        assert!(e
            .round_batch_packed_into(
                &xs,
                &xbars,
                &ps,
                &panels,
                0.5,
                0.5,
                &mut ws,
                &mut out_xs,
                &mut out_xbars
            )
            .is_err());
        // and too few panel sets
        assert!(e
            .round_batch_packed_into(
                &xs,
                &xbars,
                &ps,
                &[],
                0.5,
                0.5,
                &mut ws,
                &mut out_xs,
                &mut out_xbars
            )
            .is_err());
    }

    #[test]
    fn factorize_retains_panels_of_the_projector() {
        let e = NativeEngine::new();
        let (a, _, _) = consistent(32, 12, 64);
        let fac = e.factorize(InitKind::Qr, &a, 12).unwrap();
        assert_eq!(fac.panels.m(), 12);
        assert_eq!(fac.panels.k(), 12);
        let mut fresh = blas::PrepackedPanels::from_matrix(&fac.projector);
        assert_eq!(fac.panels.panels(), fresh.panels());
        // panels follow the projector, not the block
        fresh = blas::PrepackedPanels::from_matrix(&a);
        assert_eq!(fresh.m(), 32);
    }

    #[test]
    fn resident_bytes_track_seed_variant() {
        let (l, n) = (48u64, 16u64);
        let common = l * n * 4 + n * n * 4
            + blas::packed_a_len(n as usize, n as usize) as u64 * 4;
        assert_eq!(
            resident_partition_bytes(InitKind::Qr, 48, 16),
            common + (l * n + n * n) * 4
        );
        assert_eq!(
            resident_partition_bytes(InitKind::Classical, 48, 16),
            common + n * n * 8
        );
        let (l, n) = (8u64, 24u64);
        let common = l * n * 4 + n * n * 4
            + blas::packed_a_len(n as usize, n as usize) as u64 * 4;
        assert_eq!(
            resident_partition_bytes(InitKind::Fat, 8, 24),
            common + (n * l + l * l) * 4
        );
    }

    #[test]
    fn bad_round_batch_shapes_rejected() {
        let e = NativeEngine::new();
        let xs = vec![vec![vec![0.0f32; 4]]];
        let xbars = vec![vec![0.0f32; 4]];
        let ps = vec![Matrix::eye(3)]; // wrong projector shape
        let mut ws = RoundWorkspace::default();
        let mut out_xs = vec![vec![vec![0.0f32; 4]]];
        let mut out_xbars = vec![vec![0.0f32; 4]];
        assert!(e
            .round_batch_into(
                &xs,
                &xbars,
                &ps,
                0.5,
                0.5,
                &mut ws,
                &mut out_xs,
                &mut out_xbars
            )
            .is_err());
        // zero columns
        assert!(e
            .round_batch_into(
                &xs,
                &[],
                &ps,
                0.5,
                0.5,
                &mut ws,
                &mut [],
                &mut []
            )
            .is_err());
    }

    #[test]
    fn bad_round_shapes_rejected() {
        let e = NativeEngine::new();
        let xs = vec![vec![0.0f32; 4]];
        let xbar = vec![0.0f32; 4];
        let ps = vec![Matrix::eye(3)]; // wrong projector shape
        let mut ws = RoundWorkspace::default();
        let mut out_xs = vec![vec![0.0f32; 4]];
        let mut out_xbar = vec![0.0f32; 4];
        assert!(e
            .round_into(
                &xs, &xbar, &ps, 0.5, 0.5, &mut ws, &mut out_xs,
                &mut out_xbar
            )
            .is_err());
    }
}
