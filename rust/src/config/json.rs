//! Minimal JSON parser (no serde offline) — enough for the artifact
//! manifest and run-config files: objects, arrays, strings, numbers,
//! booleans, null.  Strict on structure, permissive on whitespace.

use std::collections::BTreeMap;

use crate::error::{DapcError, Result};

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers with contextual errors.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| DapcError::Parse(format!("missing string field {key:?}")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| DapcError::Parse(format!("missing numeric field {key:?}")))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> DapcError {
        DapcError::Parse(format!("JSON error at byte {}: {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("invalid number {s:?}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("invalid \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("invalid \\u escape"))?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // copy a full UTF-8 sequence
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (self.pos + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[self.pos..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""hi\nthere""#).unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].req_str("b").unwrap(), "c");
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn manifest_shaped_document() {
        let text = r#"[
          {"name": "update_n32", "file": "update_n32.hlo.txt",
           "params": {"kind": "update", "n": 32},
           "inputs": [{"shape": [32], "dtype": "float32"}]}
        ]"#;
        let v = Json::parse(text).unwrap();
        let e = &v.as_arr().unwrap()[0];
        assert_eq!(e.req_str("name").unwrap(), "update_n32");
        assert_eq!(e.get("params").unwrap().req_usize("n").unwrap(), 32);
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(32));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{1: 2}").is_err());
        assert!(Json::parse("true false").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }
}
