//! Solve-service throughput: cold one-shot solves vs warm-session solves
//! vs column-blocked batched solves at k in {1, 8, 32}.
//!
//! The session registers the matrix once (workers factorize and retain
//! `A_j`/`P_j`/QR state), so a warm solve pays only O(l n + n^2) seeding
//! plus the epoch loop — the O(l n^2) per-partition factorization is
//! amortized across the whole stream.  The batched path additionally
//! shares each projector-row sweep (and its f32->f64 widening) across
//! all k columns.  The bench asserts the amortization ladder the service
//! layer exists for:
//!
//!   batched k=32 per-RHS  <  warm single per-RHS  <  cold per-solve
//!
//! and records everything in `BENCH_service_throughput.json`, including
//! the steady-state per-epoch time of the prepacked epoch path
//! (`warm_per_epoch_s` / `batch32_per_epoch_s` in the summary record).

use dapc::benchkit::{quick_mode, Bench, JsonReport};
use dapc::prelude::*;
use dapc::rng::seeded;
use dapc::solver::{drive_apc, ApcVariant, InProcessBackend};
use dapc::sparse::generate::GeneratorConfig;

const STREAM: usize = 32;

fn main() {
    // J = 2 keeps per-partition projectors large (n x n each): the
    // regime where the batched row-sharing actually pays
    let n = if quick_mode() { 256 } else { 512 };
    let m = 16 * n;
    let j = 2usize;
    let epochs = if quick_mode() { 20 } else { 40 };
    let shape = format!("{m}x{n}");
    let ds = GeneratorConfig::table1(m, n).generate(4181);
    let opts = SolveOptions { epochs, ..Default::default() };
    let engine = NativeEngine::new();
    let bench = Bench::default();
    let mut report = JsonReport::new("service_throughput");

    // the request stream: STREAM consistent rhs against the one matrix
    let bs: Vec<Vec<f32>> = (0..STREAM)
        .map(|i| {
            let mut g = seeded(9000 + i as u64);
            let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
            let mut b = vec![0.0f32; m];
            ds.matrix.spmv_into(&x, &mut b);
            b
        })
        .collect();

    println!(
        "=== solve-service throughput: {shape}, J = {j}, T = {epochs}, \
         stream of {STREAM} rhs ==="
    );

    // cold: every solve pays partition + QR + epochs
    let mut req = 0usize;
    let cold = bench.run("cold one-shot solve", || {
        let mut backend = InProcessBackend::new(&engine, j);
        drive_apc(
            &mut backend,
            &ds.matrix,
            &bs[req % STREAM],
            ApcVariant::Decomposed,
            &opts,
        )
        .expect("cold solve");
        req += 1;
    });
    report.add(
        &cold,
        &[("j", j as f64), ("epochs", epochs as f64)],
        &[("shape", shape.as_str()), ("mode", "cold")],
    );
    let cold_s = cold.stats.mean();

    // warm session: register once, then stream
    let mut backend = InProcessBackend::new(&engine, j);
    let mut session = SolverSession::register(
        &mut backend,
        ds.matrix.clone(),
        SessionAlgorithm::Apc(ApcVariant::Decomposed),
        opts.clone(),
    )
    .expect("register");
    let register_s = session.stats().register_time.as_secs_f64();
    println!("registration (cold init, paid once): {register_s:.4}s");

    let mut req = 0usize;
    let warm = bench.run("warm solve (k=1)", || {
        session.solve(&bs[req % STREAM]).expect("warm solve");
        req += 1;
    });
    let warm_s = warm.stats.mean();
    report.add(
        &warm,
        &[
            ("j", j as f64),
            ("epochs", epochs as f64),
            ("per_rhs_s", warm_s),
            ("register_s", register_s),
        ],
        &[("shape", shape.as_str()), ("mode", "warm-single")],
    );

    // batched: one epoch loop drives k columns
    let mut batch_per_rhs = Vec::new();
    for &k in &[1usize, 8, 32] {
        let res = bench.run(&format!("warm batch k={k}"), || {
            session.solve_batch(&bs[..k]).expect("batched solve");
        });
        let per_rhs = res.stats.mean() / k as f64;
        println!("  -> k={k}: {:.6}s per rhs", per_rhs);
        report.add(
            &res,
            &[
                ("j", j as f64),
                ("epochs", epochs as f64),
                ("k", k as f64),
                ("per_rhs_s", per_rhs),
            ],
            &[("shape", shape.as_str()), ("mode", "warm-batch")],
        );
        batch_per_rhs.push((k, per_rhs));
    }

    let amortized = session
        .stats()
        .amortized_per_rhs()
        .expect("served rhs")
        .as_secs_f64();
    println!("{}", session.stats().summary());
    println!(
        "cold {cold_s:.6}s | warm single {warm_s:.6}s ({:.1}x) | batch k=32 \
         {:.6}s per rhs ({:.1}x)",
        cold_s / warm_s,
        batch_per_rhs[2].1,
        cold_s / batch_per_rhs[2].1,
    );
    // steady-state per-epoch view: what one prepacked projector sweep
    // costs once the session is warm (seeding/residual overhead divided
    // out across the epoch count)
    let warm_per_epoch = warm_s / epochs as f64;
    let batch32_per_epoch = batch_per_rhs[2].1 * 32.0 / epochs as f64;
    println!(
        "steady state: {warm_per_epoch:.6}s per epoch (k=1), \
         {batch32_per_epoch:.6}s per epoch (k=32)"
    );
    report.add(
        &Bench::new(0, 1).run_once("summary", || {}),
        &[
            ("cold_solve_s", cold_s),
            ("warm_per_solve_s", warm_s),
            ("batch32_per_rhs_s", batch_per_rhs[2].1),
            ("warm_per_epoch_s", warm_per_epoch),
            ("batch32_per_epoch_s", batch32_per_epoch),
            ("register_s", register_s),
            ("amortized_per_rhs_s", amortized),
        ],
        &[("shape", shape.as_str()), ("mode", "summary")],
    );

    // the amortization ladder this subsystem exists for
    assert!(
        warm_s < cold_s,
        "warm per-solve ({warm_s:.6}s) must beat the cold solve \
         ({cold_s:.6}s): factorization reuse is broken"
    );
    assert!(
        batch_per_rhs[2].1 < warm_s,
        "batched k=32 per-rhs ({:.6}s) must beat the single-rhs warm solve \
         ({warm_s:.6}s): column blocking is broken",
        batch_per_rhs[2].1
    );

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
