//! The distributed consensus backend and the leader facade.
//!
//! The epoch loop itself lives in [`crate::solver::driver`] — this module
//! only implements *where* the rounds execute: [`ClusterBackend`]
//! scatters each round over `Vec<Transport>` (pipelined: all J requests
//! go out before the first reply is awaited), gathers replies
//! out-of-order keyed on the embedded `worker_id` (a straggler in slot 0
//! no longer serializes reply processing), and streams the fixed-order
//! f64 accumulation the driver's eq. (7) mixing consumes.
//!
//! The leader owns only n-length vectors; all O(l n) / O(n^2) state stays
//! on the workers.  Per-worker estimate slots are reused across epochs,
//! so steady-state leader traffic causes no per-epoch memory growth.
//!
//! When metrics are enabled ([`crate::obs`]), every scatter/gather is
//! traced: per-worker send and reply latency histograms
//! (`cluster.scatter_ns.w{i}` / `cluster.gather_ns.w{i}`) and per-frame-
//! kind wire accounting (`wire.{tx,rx}_{frames,bytes}.{label}`).  Worker-
//! side telemetry crosses the wire on demand via the v4
//! `StatsRequest`/`StatsReport` frames ([`ClusterBackend::
//! collect_worker_stats`]).  None of this touches the numeric path.

use std::sync::Arc;

use crate::error::{DapcError, Result};
use crate::obs::{self, Counter, Histogram};
use crate::partition::PartitionPlan;
use crate::solver::driver::{
    accumulate_sum, accumulate_sum_batch, ConsensusBackend, RoundOutcome,
};
use crate::solver::{
    drive_apc, drive_dgd, ApcVariant, InitKind, RequestId, SessionBackend,
    SessionId, SolveOptions, SolveReport,
};
use crate::sparse::CsrMatrix;

use super::message::{InitKindWire, Message, KIND_LABELS};
use super::transport::{Transport, FRAME_OVERHEAD};

/// Fruitless polling passes over all pending workers before the gather
/// falls back to a blocking receive on the first straggler (avoids a
/// busy-wait on quiet TCP links while keeping the common case lock-step
/// free).
const GATHER_SPIN_PASSES: usize = 256;

/// One worker's wire telemetry: `(worker_id, flat registry snapshot)` as
/// carried by a v4 `StatsReport` frame.
pub type WorkerStats = (u32, Vec<(String, f64)>);

/// Leader-side metric handles, resolved from the global registry once at
/// backend construction so the scatter/gather hot path records lock-free.
///
/// Per-worker latency is indexed by transport slot (scatter) or by the
/// reply's own `worker_id` (gather); per-kind wire counters are indexed
/// by [`Message::kind_index`] into [`KIND_LABELS`].
struct ClusterObs {
    scatter_ns: Vec<Arc<Histogram>>,
    gather_ns: Vec<Arc<Histogram>>,
    tx_frames: Vec<Arc<Counter>>,
    tx_bytes: Vec<Arc<Counter>>,
    rx_frames: Vec<Arc<Counter>>,
    rx_bytes: Vec<Arc<Counter>>,
}

impl ClusterObs {
    fn new(j: usize) -> Self {
        Self {
            scatter_ns: (0..j)
                .map(|i| obs::histogram(&format!("cluster.scatter_ns.w{i}")))
                .collect(),
            gather_ns: (0..j)
                .map(|i| obs::histogram(&format!("cluster.gather_ns.w{i}")))
                .collect(),
            tx_frames: KIND_LABELS
                .iter()
                .map(|l| obs::counter(&format!("wire.tx_frames.{l}")))
                .collect(),
            tx_bytes: KIND_LABELS
                .iter()
                .map(|l| obs::counter(&format!("wire.tx_bytes.{l}")))
                .collect(),
            rx_frames: KIND_LABELS
                .iter()
                .map(|l| obs::counter(&format!("wire.rx_frames.{l}")))
                .collect(),
            rx_bytes: KIND_LABELS
                .iter()
                .map(|l| obs::counter(&format!("wire.rx_bytes.{l}")))
                .collect(),
        }
    }

    /// Account one received frame (kind + framed wire size).
    fn note_rx(&self, msg: &Message) {
        if !obs::enabled() {
            return;
        }
        let k = msg.kind_index();
        self.rx_frames[k].inc();
        self.rx_bytes[k].add(msg.encoded_len() as u64 + FRAME_OVERHEAD);
    }

    /// Account one sent frame (kind + framed wire size).
    fn note_tx(&self, msg: &Message) {
        if !obs::enabled() {
            return;
        }
        let k = msg.kind_index();
        self.tx_frames[k].inc();
        self.tx_bytes[k].add(msg.encoded_len() as u64 + FRAME_OVERHEAD);
    }
}

/// Send with scatter latency + per-kind tx accounting for worker slot `i`.
fn send_traced<T: Transport>(
    w: &mut T,
    i: usize,
    msg: &Message,
    cobs: &ClusterObs,
) -> Result<()> {
    let t0 = obs::now();
    w.send(msg)?;
    if let Some(h) = cobs.scatter_ns.get(i) {
        obs::record_since(h, t0);
    }
    cobs.note_tx(msg);
    Ok(())
}

/// Every reply slot must be claimed by a DISTINCT worker id: a duplicate
/// would silently clobber one slot and leave another holding the previous
/// epoch's stale estimate — wrong results with no error.
fn mark_seen(seen: &mut [bool], wid: usize) -> Result<()> {
    if wid >= seen.len() {
        return Err(DapcError::Coordinator(format!(
            "reply from unknown worker id {wid} (cluster has {})",
            seen.len()
        )));
    }
    if seen[wid] {
        return Err(DapcError::Coordinator(format!(
            "duplicate reply for worker id {wid}: two connections claim \
             the same worker (same address listed twice?)"
        )));
    }
    seen[wid] = true;
    Ok(())
}

/// Poll every pending worker, dispatching replies in ARRIVAL order; the
/// caller's `on_msg` keys state on the reply's own `worker_id` and
/// returns it so each id is verified to answer exactly once.  Falls back
/// to a blocking receive once nothing has arrived for a while.
fn gather<T, F>(
    workers: &mut [T],
    done: &mut Vec<bool>,
    seen: &mut Vec<bool>,
    cobs: &ClusterObs,
    mut on_msg: F,
) -> Result<()>
where
    T: Transport,
    F: FnMut(Message) -> Result<u32>,
{
    let j = workers.len();
    done.clear();
    done.resize(j, false);
    seen.clear();
    seen.resize(j, false);
    // per-worker gather latency = gather start -> that worker's reply
    // dispatched; frame kind/size must be noted BEFORE on_msg consumes
    // the message
    let start = obs::now();
    let mut dispatch = |msg: Message, on_msg: &mut F| -> Result<u32> {
        cobs.note_rx(&msg);
        let wid = on_msg(msg)?;
        if let Some(h) = cobs.gather_ns.get(wid as usize) {
            obs::record_since(h, start);
        }
        Ok(wid)
    };
    let mut remaining = j;
    let mut idle_passes = 0usize;
    while remaining > 0 {
        let mut progressed = false;
        for (i, w) in workers.iter_mut().enumerate() {
            if done[i] {
                continue;
            }
            if let Some(msg) = w.try_recv()? {
                let wid = dispatch(msg, &mut on_msg)?;
                mark_seen(seen, wid as usize)?;
                done[i] = true;
                remaining -= 1;
                progressed = true;
            }
        }
        if remaining == 0 {
            break;
        }
        if progressed {
            idle_passes = 0;
            continue;
        }
        idle_passes += 1;
        if idle_passes < GATHER_SPIN_PASSES {
            std::thread::yield_now();
            continue;
        }
        // nothing arriving: block on the first pending worker; whoever
        // finished meanwhile is drained by the next polling pass
        let i = done.iter().position(|d| !d).expect("remaining > 0");
        let msg = workers[i].recv()?;
        let wid = dispatch(msg, &mut on_msg)?;
        mark_seen(seen, wid as usize)?;
        done[i] = true;
        remaining -= 1;
        idle_passes = 0;
    }
    Ok(())
}

/// Since wire v5 every session reply echoes the `(session_id,
/// request_id)` pair of the request it answers; a mismatch means the
/// mux paired a reply with the wrong in-flight request — refuse loudly
/// rather than risk feeding one session's estimates into another's
/// accumulator.
fn check_reply_ids(
    worker_id: u32,
    what: &str,
    got_sid: SessionId,
    got_rid: RequestId,
    sid: SessionId,
    rid: RequestId,
) -> Result<()> {
    if got_sid != sid || got_rid != rid {
        return Err(DapcError::Coordinator(format!(
            "worker {worker_id} {what} reply names session {got_sid} \
             request {got_rid}, expected session {sid} request {rid} \
             (cross-session reply desync)"
        )));
    }
    Ok(())
}

/// Validate a worker's batched session reply: exactly `k` columns, each
/// of width `n` — shared by every v3 gather so the error shape (and any
/// future tightening) lives once.
fn check_reply_columns(
    worker_id: u32,
    what: &str,
    cols: &[Vec<f32>],
    k: usize,
    n: usize,
) -> Result<()> {
    if cols.len() != k || cols.iter().any(|c| c.len() != n) {
        return Err(DapcError::Coordinator(format!(
            "worker {worker_id} returned {} {what} columns (lengths {:?}) \
             != {k} columns of n = {n}",
            cols.len(),
            cols.iter().map(Vec::len).collect::<Vec<_>>()
        )));
    }
    Ok(())
}

/// [`ConsensusBackend`] over J connected worker transports.
pub struct ClusterBackend<T: Transport> {
    workers: Vec<T>,
    /// Per-worker estimate slots, reused across epochs (the only
    /// per-worker state the leader holds).
    xs: Vec<Vec<f32>>,
    /// Per-worker per-column estimate slots for batched session solves
    /// (`batch_xs[worker][column]`), reused across epochs.
    batch_xs: Vec<Vec<Vec<f32>>>,
    /// Reused gather bookkeeping (per-transport completion, per-id
    /// uniqueness).
    done: Vec<bool>,
    seen: Vec<bool>,
    epoch: u32,
    n_target: usize,
    /// Per-session leader bookkeeping, keyed by [`SessionId`] (wire v5
    /// multi-tenant service).  Deliberately tiny — the leader's O(n)
    /// state guarantee is per *solve*, not per session: all heavy
    /// per-session state (factorizations, packed panels) lives on the
    /// workers; the leader only remembers each session's width and the
    /// id of its in-flight request.
    sessions: std::collections::BTreeMap<SessionId, LeaderSession>,
    /// Monotonic request-id allocator (casparianflow-style job ids);
    /// every registration/seed allocates a fresh id, echoed by workers.
    next_request_id: RequestId,
    /// Metric handles (scatter/gather latency, per-kind wire counters),
    /// resolved once so the hot path records without registry locks.
    obs: ClusterObs,
}

/// Per-session leader state (see [`ClusterBackend::sessions`]).
struct LeaderSession {
    /// Solution width the session's consensus loop runs at.
    n_target: usize,
    /// Request id of the session's current solve; allocated by
    /// `seed_rhs`/`seed_grad_rhs`, reused by every round frame of that
    /// solve, verified against every reply.
    active_req: RequestId,
}

impl<T: Transport> ClusterBackend<T> {
    /// Backend over the given worker connections; rejects an empty
    /// cluster up front (every later step would need `J >= 1`).
    pub fn new(workers: Vec<T>) -> Result<Self> {
        if workers.is_empty() {
            return Err(DapcError::Coordinator(
                "cluster needs at least one worker (got 0): there is no \
                 worker to hold a partition"
                    .into(),
            ));
        }
        let j = workers.len();
        Ok(Self {
            workers,
            xs: vec![Vec::new(); j],
            batch_xs: vec![Vec::new(); j],
            done: Vec::new(),
            seen: Vec::new(),
            epoch: 0,
            n_target: 0,
            sessions: std::collections::BTreeMap::new(),
            next_request_id: 0,
            obs: ClusterObs::new(j),
        })
    }

    fn next_rid(&mut self) -> RequestId {
        self.next_request_id += 1;
        self.next_request_id
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Total wire traffic so far as `(bytes_sent, bytes_received)`,
    /// summed over all worker links (framing included).
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.workers.iter().fold((0, 0), |(s, r), w| {
            (s + w.bytes_sent(), r + w.bytes_received())
        })
    }

    /// Send shutdown to all workers (best-effort).
    pub fn shutdown(&mut self) {
        for (i, w) in self.workers.iter_mut().enumerate() {
            let _ = send_traced(w, i, &Message::Shutdown, &self.obs);
        }
    }

    /// Poll every worker for its telemetry snapshot (wire v4
    /// `StatsRequest`/`StatsReport`); returns `(worker_id, stats)` pairs
    /// in worker-id order.  `stats` is the flat snapshot of the worker's
    /// registry (`crate::obs::MetricsRegistry::snapshot_flat`).  Note:
    /// in-process workers share this process's global registry, so their
    /// reports all mirror the same aggregate; the per-worker split is
    /// exact only across process boundaries (TCP workers).
    pub fn collect_worker_stats(&mut self) -> Result<Vec<WorkerStats>> {
        let j = self.workers.len();
        for (i, w) in self.workers.iter_mut().enumerate() {
            send_traced(w, i, &Message::StatsRequest, &self.obs)?;
        }
        let mut reports: Vec<Option<WorkerStats>> = vec![None; j];
        let slots = &mut reports;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::StatsReport { worker_id, stats } => {
                    if let Some(slot) = slots.get_mut(worker_id as usize) {
                        *slot = Some((worker_id, stats));
                    }
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} stats report failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        Ok(reports.into_iter().flatten().collect())
    }

    /// Pipelined scatter of per-worker partition blocks.
    fn scatter_blocks(
        &mut self,
        kind: InitKindWire,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()> {
        for (i, w) in self.workers.iter_mut().enumerate() {
            let (sub, rhs) = plan.extract(a, b, i);
            let msg = Message::InitPartition {
                worker_id: i as u32,
                kind,
                a: sub,
                b: rhs,
                n_target: plan.n as u32,
            };
            send_traced(w, i, &msg, &self.obs)?;
        }
        Ok(())
    }

    /// Session registration: scatter `RegisterMatrix` blocks under
    /// `sid` (workers factorize once and keep the state keyed by
    /// session id) and gather the acks, verifying each echoes the
    /// registration's `(session_id, request_id)`.
    fn register_wire(
        &mut self,
        sid: SessionId,
        kind: InitKindWire,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<()> {
        let rid = self.next_rid();
        for (i, w) in self.workers.iter_mut().enumerate() {
            let blk = plan.blocks[i];
            let sub = a.slice_rows_dense(blk.start, blk.end);
            let msg = Message::RegisterMatrix {
                worker_id: i as u32,
                session_id: sid,
                request_id: rid,
                kind,
                a: sub,
                n_target: plan.n as u32,
            };
            send_traced(w, i, &msg, &self.obs)?;
        }
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::MatrixRegistered {
                    worker_id,
                    session_id,
                    request_id,
                } => {
                    check_reply_ids(
                        worker_id,
                        "registration",
                        session_id,
                        request_id,
                        sid,
                        rid,
                    )?;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} registration failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        self.sessions
            .insert(sid, LeaderSession { n_target: plan.n, active_req: rid });
        Ok(())
    }

    /// `sid`'s leader bookkeeping, or the same loud unknown-session
    /// error the in-process backend raises.
    fn session(&self, sid: SessionId, what: &str) -> Result<&LeaderSession> {
        self.sessions.get(&sid).ok_or_else(|| {
            DapcError::Coordinator(format!(
                "session {sid}: {what} before register_matrix: register a \
                 matrix into the session before streaming right-hand sides"
            ))
        })
    }

    /// Pipelined scatter of per-worker rhs column slices: one
    /// `SolveRhs` frame for a single rhs, one `SolveBatch` for k > 1.
    fn scatter_rhs(
        &mut self,
        sid: SessionId,
        rid: RequestId,
        plan: &PartitionPlan,
        bs: &[&[f32]],
    ) -> Result<()> {
        let m = plan.blocks.last().map(|b| b.end).unwrap_or(0);
        for b in bs {
            if b.len() != m {
                return Err(DapcError::Shape(format!(
                    "rhs length {} != matrix rows {m}",
                    b.len()
                )));
            }
        }
        for (i, w) in self.workers.iter_mut().enumerate() {
            let blk = plan.blocks[i];
            let msg = if let [b] = bs {
                Message::SolveRhs {
                    session_id: sid,
                    request_id: rid,
                    b: b[blk.start..blk.end].to_vec(),
                }
            } else {
                let cols: Vec<Vec<f32>> = bs
                    .iter()
                    .map(|b| b[blk.start..blk.end].to_vec())
                    .collect();
                Message::SolveBatch {
                    session_id: sid,
                    request_id: rid,
                    bs: cols,
                }
            };
            send_traced(w, i, &msg, &self.obs)?;
        }
        Ok(())
    }
}

impl<T: Transport> ConsensusBackend for ClusterBackend<T> {
    fn partitions(&self) -> usize {
        self.workers.len()
    }

    fn init_partitions(
        &mut self,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
        acc: &mut Vec<f64>,
    ) -> Result<usize> {
        let n = plan.n;
        self.n_target = n;
        self.scatter_blocks(kind.into(), plan, a, b)?;
        let xs = &mut self.xs;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::InitDone { worker_id, x0 } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "InitDone from unknown worker {worker_id}"
                            ))
                        })?;
                    if x0.len() != n {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} returned x0 of length {} \
                             != n = {n}",
                            x0.len()
                        )));
                    }
                    *slot = x0;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} init failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        acc.clear();
        acc.resize(n, 0.0);
        accumulate_sum(&self.xs, acc);
        Ok(n)
    }

    fn run_round(
        &mut self,
        gamma: f32,
        _eta: f32,
        xbar: &mut [f32],
        acc: &mut [f64],
    ) -> Result<RoundOutcome> {
        let msg = Message::RunUpdate {
            epoch: self.epoch,
            gamma,
            xbar: xbar.to_vec(),
        };
        self.epoch = self.epoch.wrapping_add(1);
        // pipelined scatter: workers compute eq. (6) concurrently
        for (i, w) in self.workers.iter_mut().enumerate() {
            send_traced(w, i, &msg, &self.obs)?;
        }
        let n = self.n_target;
        let xs = &mut self.xs;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::UpdateDone { worker_id, x } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "UpdateDone from unknown worker {worker_id}"
                            ))
                        })?;
                    if x.len() != n {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} returned estimate of \
                             length {} != n = {n}",
                            x.len()
                        )));
                    }
                    *slot = x;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} update failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        // fixed-order f64 reduction; the driver applies eq. (7)
        accumulate_sum(&self.xs, acc);
        Ok(RoundOutcome::Accumulated)
    }

    fn init_grad(
        &mut self,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()> {
        self.n_target = plan.n;
        // GradOnly: workers store their block and skip the (for DGD
        // useless) O(l n^2) factorization entirely
        self.scatter_blocks(InitKindWire::GradOnly, plan, a, b)?;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::InitDone { worker_id, .. } => Ok(worker_id),
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} init failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })
    }

    fn grad_round(&mut self, x: &[f32], acc: &mut [f64]) -> Result<()> {
        let msg = Message::RunGrad { epoch: self.epoch, x: x.to_vec() };
        self.epoch = self.epoch.wrapping_add(1);
        for (i, w) in self.workers.iter_mut().enumerate() {
            send_traced(w, i, &msg, &self.obs)?;
        }
        let n = self.n_target;
        let xs = &mut self.xs;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::GradDone { worker_id, grad } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "GradDone from unknown worker {worker_id}"
                            ))
                        })?;
                    if grad.len() != n {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} returned gradient of \
                             length {} != n = {n}",
                            grad.len()
                        )));
                    }
                    *slot = grad;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} grad failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        accumulate_sum(&self.xs, acc);
        Ok(())
    }

    fn x_parts(&mut self) -> Result<Vec<Vec<f32>>> {
        Ok(self.xs.clone())
    }

    fn backend_name(&self) -> &'static str {
        "distributed"
    }
}

impl<T: Transport> SessionBackend for ClusterBackend<T> {
    fn register_matrix(
        &mut self,
        sid: SessionId,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<usize> {
        self.register_wire(sid, kind.into(), plan, a)?;
        Ok(plan.n)
    }

    fn register_grad(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<()> {
        self.register_wire(sid, InitKindWire::GradOnly, plan, a)
    }

    fn seed_rhs(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        bs: &[&[f32]],
        accs: &mut [Vec<f64>],
    ) -> Result<()> {
        let n = self.session(sid, "seed_rhs")?.n_target;
        let k = bs.len();
        // a fresh solve: allocate its request id, reused by every round
        let rid = self.next_rid();
        self.sessions
            .get_mut(&sid)
            .expect("session checked above")
            .active_req = rid;
        self.scatter_rhs(sid, rid, plan, bs)?;
        let xs = &mut self.batch_xs;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::RhsSeeded {
                    worker_id,
                    session_id,
                    request_id,
                    x0s,
                } => {
                    check_reply_ids(
                        worker_id, "seed", session_id, request_id, sid, rid,
                    )?;
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "RhsSeeded from unknown worker {worker_id}"
                            ))
                        })?;
                    check_reply_columns(worker_id, "seeded", &x0s, k, n)?;
                    *slot = x0s;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} seed failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        for acc in accs.iter_mut() {
            acc.clear();
            acc.resize(n, 0.0);
        }
        accumulate_sum_batch(&self.batch_xs, accs);
        Ok(())
    }

    fn seed_grad_rhs(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        bs: &[&[f32]],
    ) -> Result<()> {
        self.session(sid, "seed_grad_rhs")?;
        let k = bs.len();
        let rid = self.next_rid();
        self.sessions
            .get_mut(&sid)
            .expect("session checked above")
            .active_req = rid;
        self.scatter_rhs(sid, rid, plan, bs)?;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::RhsSeeded {
                    worker_id,
                    session_id,
                    request_id,
                    x0s,
                } => {
                    check_reply_ids(
                        worker_id, "seed", session_id, request_id, sid, rid,
                    )?;
                    // gradient-only sessions return k empty columns
                    if x0s.len() != k {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} acknowledged {} rhs \
                             columns, expected {k}",
                            x0s.len()
                        )));
                    }
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} seed failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })
    }

    fn run_round_batch(
        &mut self,
        sid: SessionId,
        gamma: f32,
        _eta: f32,
        xbars: &mut [Vec<f32>],
        accs: &mut [Vec<f64>],
    ) -> Result<RoundOutcome> {
        let sess = self.session(sid, "run_round_batch")?;
        let (n, rid) = (sess.n_target, sess.active_req);
        let msg = Message::RunUpdateBatch {
            session_id: sid,
            request_id: rid,
            epoch: self.epoch,
            gamma,
            xbars: xbars.to_vec(),
        };
        self.epoch = self.epoch.wrapping_add(1);
        for (i, w) in self.workers.iter_mut().enumerate() {
            send_traced(w, i, &msg, &self.obs)?;
        }
        let k = xbars.len();
        let xs = &mut self.batch_xs;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::UpdateBatchDone {
                    worker_id,
                    session_id,
                    request_id,
                    xs: cols,
                } => {
                    check_reply_ids(
                        worker_id, "update", session_id, request_id, sid, rid,
                    )?;
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "UpdateBatchDone from unknown worker \
                                 {worker_id}"
                            ))
                        })?;
                    check_reply_columns(worker_id, "estimate", &cols, k, n)?;
                    *slot = cols;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} batched update failed: \
                         {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        // fixed-order f64 reduction per column; the driver mixes eq. (7)
        accumulate_sum_batch(&self.batch_xs, accs);
        Ok(RoundOutcome::Accumulated)
    }

    fn grad_round_batch(
        &mut self,
        sid: SessionId,
        xs_cols: &[Vec<f32>],
        accs: &mut [Vec<f64>],
    ) -> Result<()> {
        let sess = self.session(sid, "grad_round_batch")?;
        let (n, rid) = (sess.n_target, sess.active_req);
        let msg = Message::RunGradBatch {
            session_id: sid,
            request_id: rid,
            epoch: self.epoch,
            xs: xs_cols.to_vec(),
        };
        self.epoch = self.epoch.wrapping_add(1);
        for (i, w) in self.workers.iter_mut().enumerate() {
            send_traced(w, i, &msg, &self.obs)?;
        }
        let k = xs_cols.len();
        let xs = &mut self.batch_xs;
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::GradBatchDone {
                    worker_id,
                    session_id,
                    request_id,
                    grads,
                } => {
                    check_reply_ids(
                        worker_id, "gradient", session_id, request_id, sid,
                        rid,
                    )?;
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "GradBatchDone from unknown worker \
                                 {worker_id}"
                            ))
                        })?;
                    check_reply_columns(worker_id, "gradient", &grads, k, n)?;
                    *slot = grads;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} batched gradient failed: \
                         {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        accumulate_sum_batch(&self.batch_xs, accs);
        Ok(())
    }

    fn unregister_session(&mut self, sid: SessionId) -> Result<()> {
        // scatter the eviction even when the leader no longer tracks
        // `sid` — unregistration must be idempotent, and workers ack
        // absent ids as a no-op
        for (i, w) in self.workers.iter_mut().enumerate() {
            send_traced(
                w,
                i,
                &Message::EvictSession { session_id: sid },
                &self.obs,
            )?;
        }
        let cobs = &self.obs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, cobs, |msg| {
            match msg {
                Message::SessionEvicted { worker_id, session_id } => {
                    if session_id != sid {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} acked eviction of session \
                             {session_id}, expected {sid}"
                        )));
                    }
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} eviction failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        self.sessions.remove(&sid);
        Ok(())
    }
}

/// Leader over J connected workers — an ergonomic facade that runs the
/// shared driver over a [`ClusterBackend`].
pub struct Leader<T: Transport> {
    backend: ClusterBackend<T>,
}

impl<T: Transport> Leader<T> {
    /// Leader over the given worker connections (`J >= 1`).
    pub fn new(workers: Vec<T>) -> Result<Self> {
        Ok(Self { backend: ClusterBackend::new(workers)? })
    }

    pub fn worker_count(&self) -> usize {
        self.backend.worker_count()
    }

    /// The underlying backend, for driving
    /// [`crate::solver::drive_apc`]/[`crate::solver::drive_dgd`] directly.
    pub fn backend_mut(&mut self) -> &mut ClusterBackend<T> {
        &mut self.backend
    }

    /// Total `(sent, received)` wire bytes across all worker links.
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.backend.wire_bytes()
    }

    /// Gather each worker's telemetry snapshot over the wire (see
    /// [`ClusterBackend::collect_worker_stats`]).
    pub fn collect_worker_stats(&mut self) -> Result<Vec<WorkerStats>> {
        self.backend.collect_worker_stats()
    }

    /// Run the APC consensus algorithm distributed over the workers.
    pub fn solve_apc(
        &mut self,
        a: &CsrMatrix,
        b: &[f32],
        variant: ApcVariant,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        drive_apc(&mut self.backend, a, b, variant, opts)
    }

    /// Distributed gradient descent over the same workers (step size
    /// from [`SolveOptions::dgd_step`]; `<= 0` selects the automatic
    /// Gershgorin bound).
    pub fn solve_dgd(
        &mut self,
        a: &CsrMatrix,
        b: &[f32],
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        drive_dgd(&mut self.backend, a, b, opts)
    }

    /// Send shutdown to all workers (best-effort).
    pub fn shutdown(&mut self) {
        self.backend.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::{channel_pair, ChannelTransport};
    use crate::linalg::Matrix;

    #[test]
    fn duplicate_worker_ids_rejected() {
        // two connections claiming the same worker id would silently
        // leave one slot stale; the gather must refuse instead
        let (l0, mut w0) = channel_pair();
        let (l1, mut w1) = channel_pair();
        let n = 4;
        w0.send(&Message::InitDone { worker_id: 0, x0: vec![0.0; n] })
            .unwrap();
        w1.send(&Message::InitDone { worker_id: 0, x0: vec![0.0; n] })
            .unwrap();

        let mut backend = ClusterBackend::new(vec![l0, l1]).unwrap();
        let a = CsrMatrix::from_dense(&Matrix::from_fn(8, n, |i, j| {
            (i + j) as f32 + 1.0
        }));
        let b = vec![1.0f32; 8];
        let plan = PartitionPlan::contiguous(8, n, 2).unwrap();
        let mut acc = Vec::new();
        let err = backend
            .init_partitions(InitKind::Qr, &plan, &a, &b, &mut acc)
            .unwrap_err();
        assert!(
            err.to_string().contains("duplicate reply"),
            "unexpected error: {err}"
        );
        drop((w0, w1));
    }

    #[test]
    fn collect_worker_stats_orders_reports_and_accounts_wire() {
        let _guard = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        // reports queued out of id order: the gather keys on worker_id
        let (l0, mut w0) = channel_pair();
        let (l1, mut w1) = channel_pair();
        w1.send(&Message::StatsReport {
            worker_id: 1,
            stats: vec![("worker.frames".into(), 3.0)],
        })
        .unwrap();
        w0.send(&Message::StatsReport { worker_id: 0, stats: vec![] })
            .unwrap();

        let mut backend = ClusterBackend::new(vec![l0, l1]).unwrap();
        let reports = backend.collect_worker_stats().unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].0, 0);
        assert_eq!(reports[1].0, 1);
        assert_eq!(
            reports[1].1,
            vec![("worker.frames".to_string(), 3.0)]
        );
        // wire accounting saw the request going out and the reports
        // coming back, under their own frame-kind labels
        assert!(obs::counter("wire.tx_frames.stats_request").get() >= 2);
        assert!(obs::counter("wire.rx_frames.stats_report").get() >= 2);
        assert!(
            obs::counter("wire.rx_bytes.stats_report").get()
                >= 2 * FRAME_OVERHEAD
        );
        crate::obs::set_enabled(false);
        drop((w0, w1));
    }

    #[test]
    fn zero_worker_cluster_rejected_with_coordinator_error() {
        // used to panic deep inside the solve (`xs[0]` on an empty vec);
        // now both entry points refuse up front with a clear message
        for result in [
            ClusterBackend::<ChannelTransport>::new(vec![]).map(|_| ()),
            Leader::<ChannelTransport>::new(vec![]).map(|_| ()),
        ] {
            match result {
                Err(DapcError::Coordinator(msg)) => {
                    assert!(msg.contains("at least one worker"), "{msg}")
                }
                other => panic!("expected Coordinator error, got {other:?}"),
            }
        }
    }
}
