"""Artifact shape manifest.

AOT-lowered HLO has static shapes, so every (J, l, n) problem configuration
the rust runtime wants to execute needs its own artifact set.  This module
is the single source of truth for which configurations get built:

* ``DEFAULT_PROBLEMS`` — small/medium buckets used by tests, examples and
  the scaled-down benches (built by plain ``make artifacts``).
* ``FULL_PROBLEMS``    — the five paper-scale Table-1 shapes (m = 4n rows,
  J = 2 workers), padded up to 128-multiples; built with
  ``make artifacts FULL=1``.

The rust ``partition::bucket`` module pads real datasets (extra zero rows /
block-diagonal identity columns) up to the nearest manifest entry — padding
is exact for QR/backsub/projection, see DESIGN.md §3.
"""

from __future__ import annotations

import dataclasses


def _pad(v: int, mult: int = 128) -> int:
    return ((v + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class Problem:
    """One (J, l, n) configuration: J partitions of l x n blocks."""

    j: int
    l: int  # rows per partition (tall: l >= n, fat: l < n)
    n: int  # columns / solution dimension

    @property
    def tall(self) -> bool:
        return self.l >= self.n

    def tag(self) -> str:
        return f"j{self.j}_l{self.l}_n{self.n}"


# Small buckets: unit/integration tests, quickstart example.
# Medium buckets: convergence example (scaled c-27-like), default benches.
DEFAULT_PROBLEMS: list[Problem] = [
    Problem(j=2, l=64, n=32),
    Problem(j=4, l=64, n=32),
    Problem(j=2, l=256, n=128),
    Problem(j=4, l=256, n=128),
    Problem(j=2, l=1024, n=512),  # scaled c-27: n=512, m=4n, J=2 blocks
    Problem(j=4, l=32, n=128),    # fat regime (original APC [7])
]

# Paper Table-1 shapes (A is the pre-augmented (m x n), m = 4n; w = 2
# workers per the table caption).  l = m / J padded to a 128-multiple;
# n likewise.  Row/column padding is exact (DESIGN.md §3).
_TABLE1_MN = [
    (9308, 2327),
    (15188, 3797),
    (18252, 4563),
    (21284, 5321),
    (37084, 9271),
]

FULL_PROBLEMS: list[Problem] = [
    Problem(j=2, l=_pad(m // 2), n=_pad(n)) for (m, n) in _TABLE1_MN
]


def problems(full: bool = False) -> list[Problem]:
    out = list(DEFAULT_PROBLEMS)
    if full:
        out.extend(FULL_PROBLEMS)
    return out
