//! Runtime-dispatched SIMD kernels with a **lane-deterministic scalar
//! contract**.
//!
//! Every hot linalg primitive (`dot`, `dot_wide`, `axpy`, `widen`, and
//! the gemm `MR x NR` microkernel) exists twice in this module: an
//! explicit AVX2(+FMA) implementation (`std::arch::x86_64` intrinsics,
//! `unsafe` confined to the intrinsic bodies) and a scalar fallback.
//! [`active`] picks one **once per process** (cached in a `OnceLock`):
//! AVX2+FMA when `is_x86_feature_detected!` reports both features,
//! scalar otherwise — and `DAPC_FORCE_SCALAR=1` forces the scalar path
//! regardless, which is how CI covers both legs on the same hardware.
//!
//! # The lane contract — why dispatch can never change a result
//!
//! The repo's equivalence suites (`tests/distributed_equivalence.rs`,
//! `tests/parallel_engine.rs`) assert **bitwise** equality: cross-engine,
//! warm == cold, batch == sequential, pooled == serial.  A kernel layer
//! whose vector and scalar paths rounded differently would silently key
//! every one of those invariants on the CPU the test ran on.  Instead,
//! the two paths are bit-identical *by construction*:
//!
//! * **Reductions** (`dot`, `dot_wide`) accumulate into a fixed array of
//!   [`LANES`] = 8 independent f64 accumulators — lane `l` only ever sees
//!   elements `i` with `i % 8 == l` — followed by one fixed horizontal
//!   reduction tree `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))` and a
//!   separate sequential tail for the `n % 8` remainder, *added last*.
//!   The scalar fallback is restructured into exactly this shape, so the
//!   AVX2 path (two 4-lane `__m256d` accumulators, the same tree via
//!   `vaddpd`/`vextractf128`/`vunpckhpd`) performs the identical
//!   sequence of f64 roundings.
//! * **FMA is used only where it is provably exact-equivalent.**  `dot`
//!   multiplies two *widened* f32 values in f64: a 24-bit x 24-bit
//!   mantissa product fits in 48 < 53 bits, so the product is exact and
//!   `fma(x, y, acc)` rounds at the same single point as
//!   `acc + (x * y)` — bit-identical.  `dot_wide` takes an *arbitrary*
//!   f64 left operand (53-bit x 24-bit products do not fit), so both its
//!   paths round the product first (`mul` then `add`), matching the
//!   scalar `acc += x * y as f64` for every input, widened or not.
//! * **Elementwise f32 kernels** (`axpy`, `widen`, the tier-0 gemm
//!   microkernel) carry no cross-lane reduction at all: output element
//!   `(i, j)` is the same chain of scalar f32 roundings on both paths
//!   (`mul` + `add`, never f32 FMA on tier-0 — a fused f32 multiply-add
//!   rounds once where the scalar fallback rounds twice, and emulating
//!   fused rounding in scalar code costs more than it saves).
//!
//! Net effect: like the thread count (`parallel::ThreadPool`) and the
//! batch width (`solver::engine::update_batch_kernel`), the dispatch
//! choice is *invisible in the output bits*.  `DAPC_FORCE_SCALAR=1` is a
//! perf switch, not a numerics switch.
//!
//! The contract's preconditions are machine-enforced repo-wide by the
//! `dapc audit` static pass: `unsafe` and fused float ops are confined
//! to this file (plus the pool), and order-sensitive float reductions
//! may not appear outside `linalg/` — see CONTRIBUTING.md, "The
//! determinism contract, statically".
//!
//! # The two-tier determinism contract ([`KernelTier`])
//!
//! The gemm microkernel exists at two numerics tiers:
//!
//! * **Tier-0, [`KernelTier::Deterministic`] (default)** — the contract
//!   above, unchanged: f32 mul-then-add on every backend, bitwise across
//!   scalar/AVX2/thread count.  Every `assert_eq!` equivalence suite in
//!   the repo runs under this tier.
//! * **Tier-1, [`KernelTier::Fast`]** (`DAPC_KERNEL_TIER=fast`, or
//!   `SolveOptions::kernel_tier` per solve) — the microkernel may use
//!   *fused* f32 multiply-add ([`f32::mul_add`] on the scalar path,
//!   `vfmadd231ps` on AVX2), roughly doubling gemm peak on FMA hardware.
//!   Tier-1 results are **bitwise-reproducible within one backend** (the
//!   accumulation order is still a pure function of the element
//!   coordinates, so threads/chunking still cannot change a bit), but
//!   across backends and against tier-0 they are validated by a forward
//!   error bound (`tests/kernel_tier.rs`), not `assert_eq!`.  The tier
//!   only affects the microkernel — `dot`/`dot_wide`/`axpy`/`widen` keep
//!   the tier-0 contract always, so consensus iterates
//!   (`update_batch_kernel` etc.) are tier-independent.
//!
//! # The wide microkernel (packed panels, f64 accumulation)
//!
//! [`microkernel_wide_on`] is the epoch-loop analogue of the gemm
//! microkernel: f32 packed `MR x kc` A-panels times f32 `kc x NR`
//! B-panels, accumulated in **f64** with the exact lane discipline of
//! [`dot_on`] — per output element, depth index `p` feeds phase
//! accumulator `p % 8` over the full depth (the caller passes the whole
//! `k`, never a `KC` slice), the 8 phases fold through the shared
//! [`reduce_lanes`] tree, and the sequential `k % 8` tail joins last.
//! Every output element therefore carries the bit-exact value of
//! `dot(row_i(A), col_j(B))`: single-RHS row-dots, batch-of-k panels,
//! pooled row chunks and serial sweeps all agree by construction, which
//! is what lets the consensus epoch loop run on prepacked projector
//! panels (`blas::PrepackedPanels`) without perturbing a bit of any
//! equivalence suite.  Unlike the f32 microkernel the wide kernel
//! *overwrites* its `MR x NR` f64 output tile (no read-modify-write), so
//! its result is a pure function of the panels alone.  A tier-1 fused
//! variant ([`microkernel_wide_tier_on`] with [`KernelTier::Fast`])
//! accumulates in fused f32 (sequential over `p` per element,
//! correctly-rounded on both backends) and widens once at the end —
//! same reproducibility story as the tier-1 f32 microkernel.
//!
//! # NaN policy
//!
//! Matching `norms::max_abs`: NaN is never silently dropped.  A NaN
//! anywhere in a reduction input makes the result NaN on both paths
//! (FMA, mul and add all propagate NaN); elementwise kernels poison
//! exactly the lanes a scalar loop would.  NaN *payloads* are not part
//! of the contract — `tests/simd_lane_contract.rs` asserts NaN-ness, and
//! bitwise equality on non-NaN data.
//!
//! # Remainder handling
//!
//! Every kernel splits `n` as `8 * (n / 8) + (n % 8)`.  The vector body
//! covers the full 8-wide chunks with unaligned loads (`loadu`); the
//! remainder runs the plain sequential scalar loop on both paths, and
//! for reductions its partial sum joins *after* the lane tree.  The
//! property sweep in `tests/simd_lane_contract.rs` covers every
//! `n % 8 ∈ 0..=7` class at several magnitudes.

use std::sync::OnceLock;

/// Fixed accumulator lane count of the reduction kernels — one AVX2
/// register of f32, or two registers of f64.  Both dispatch paths
/// accumulate in exactly this many independent lanes.
pub const LANES: usize = 8;

/// Gemm microkernel tile rows (register block; see `blas` module docs
/// for the surrounding MC/KC/NC cache blocking).
pub const MR: usize = 4;

/// Gemm microkernel tile columns (register block; one 8-lane f32
/// vector, i.e. [`LANES`]).
pub const NR: usize = 8;

/// Which kernel implementation a call runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The 8-lane-structured scalar fallback (portable).
    Scalar,
    /// AVX2 + FMA intrinsics (x86-64 only, runtime-detected).
    Avx2Fma,
}

impl Backend {
    /// Short stable name, used in bench JSON records and logs.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2Fma => "avx2+fma",
        }
    }
}

/// Which numerics tier the gemm microkernel runs (module docs, "two-tier
/// determinism contract").  Only the microkernel is tiered; every other
/// kernel keeps the tier-0 contract unconditionally.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelTier {
    /// Tier-0: f32 mul-then-add, bitwise across backends and threads.
    #[default]
    Deterministic,
    /// Tier-1: fused f32 multiply-add — bitwise-reproducible within one
    /// backend, tolerance-validated across backends / against tier-0.
    Fast,
}

impl KernelTier {
    /// Short stable name, used in bench JSON records and logs.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Deterministic => "deterministic",
            KernelTier::Fast => "fast",
        }
    }
}

/// `DAPC_FORCE_SCALAR=1` forces the scalar path (any other value, or
/// unset, lets detection decide).  Reads go through the central
/// [`crate::config::envvars`] registry.
fn force_scalar_env() -> bool {
    crate::config::envvars::force_scalar()
}

/// `DAPC_KERNEL_TIER=fast` opts the process into the tier-1 microkernel
/// (any other value, or unset, keeps the deterministic default).
fn fast_tier_env() -> bool {
    crate::config::envvars::fast_tier()
}

/// The tier selection rule, split out pure so it is unit-testable
/// without mutating process environment.
pub fn select_tier(fast: bool) -> KernelTier {
    if fast {
        KernelTier::Fast
    } else {
        KernelTier::Deterministic
    }
}

static ACTIVE_TIER: OnceLock<KernelTier> = OnceLock::new();

/// The process-default kernel tier, read once from `DAPC_KERNEL_TIER`
/// and cached — callers that need a per-solve override (the engines)
/// carry an explicit [`KernelTier`] instead of re-reading this.
pub fn active_tier() -> KernelTier {
    *ACTIVE_TIER.get_or_init(|| select_tier(fast_tier_env()))
}

/// Human-readable description of the active tier and what it promises
/// (for `dapc kernels` and CI logs).
pub fn tier_description() -> &'static str {
    match active_tier() {
        KernelTier::Deterministic => {
            "tier-0 deterministic (bitwise across backends and threads)"
        }
        KernelTier::Fast => {
            "tier-1 fast (DAPC_KERNEL_TIER=fast: fused f32 rounding, \
             bitwise within a backend, tolerance-validated across)"
        }
    }
}

/// Runtime CPU support for the [`Backend::Avx2Fma`] kernels.
#[cfg(target_arch = "x86_64")]
pub fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Runtime CPU support for the [`Backend::Avx2Fma`] kernels.
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_available() -> bool {
    false
}

/// The selection rule, split out pure so it is unit-testable without
/// mutating process environment: forcing scalar always wins; otherwise
/// AVX2+FMA exactly when the CPU has it.
pub fn select(force_scalar: bool, avx2: bool) -> Backend {
    if force_scalar || !avx2 {
        Backend::Scalar
    } else {
        Backend::Avx2Fma
    }
}

/// Every backend this CPU can run, scalar first — the iteration list
/// for the lane-contract tests and the per-backend microbenches, kept
/// here so adding a backend extends their coverage automatically.
pub fn available() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    if avx2_available() {
        v.push(Backend::Avx2Fma);
    }
    v
}

static ACTIVE: OnceLock<Backend> = OnceLock::new();

/// The process-wide kernel backend, selected once on first use (env +
/// feature detection) and never changed after — a mid-run flip would be
/// harmless for the bits (see module docs) but would make perf numbers
/// unattributable.
pub fn active() -> Backend {
    *ACTIVE.get_or_init(|| select(force_scalar_env(), avx2_available()))
}

/// Human-readable description of the active backend and why it was
/// chosen (for `dapc kernels` and CI logs).
pub fn description() -> &'static str {
    match active() {
        Backend::Avx2Fma => "avx2+fma (runtime-detected)",
        Backend::Scalar => {
            if force_scalar_env() {
                "scalar (forced by DAPC_FORCE_SCALAR=1)"
            } else if avx2_available() {
                // selection was cached before the env var changed, or a
                // test called select() directly; report what is running
                "scalar (selected at startup)"
            } else {
                "scalar (avx2+fma not detected)"
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public entry points: length checks + backend routing.
//
// Each takes the backend explicitly so benches and the lane-contract
// tests can pin a path; hot callers pass `active()` (hoisted out of
// their inner loops where it matters, e.g. `blas::gemm_into`).
// ---------------------------------------------------------------------------

/// Dot product with f64 accumulation on the given backend.
///
/// Checked in release builds too: a silent length mismatch here would
/// read past the kernel's assumptions in every caller.
#[inline]
pub fn dot_on(backend: Backend, x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    match backend {
        Backend::Scalar => scalar::dot(x, y),
        Backend::Avx2Fma => dot_avx2(x, y),
    }
}

/// [`dot_on`] against a pre-widened f64 left operand.
#[inline]
pub fn dot_wide_on(backend: Backend, x: &[f64], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot_wide length mismatch");
    match backend {
        Backend::Scalar => scalar::dot_wide(x, y),
        Backend::Avx2Fma => dot_wide_avx2(x, y),
    }
}

/// `y += alpha * x` on the given backend.
#[inline]
pub fn axpy_on(backend: Backend, alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    match backend {
        Backend::Scalar => scalar::axpy(alpha, x, y),
        Backend::Avx2Fma => axpy_avx2(alpha, x, y),
    }
}

/// Exact f32 -> f64 widening into a caller buffer on the given backend.
#[inline]
pub fn widen_on(backend: Backend, src: &[f32], dst: &mut [f64]) {
    assert_eq!(src.len(), dst.len(), "widen length mismatch");
    match backend {
        Backend::Scalar => scalar::widen(src, dst),
        Backend::Avx2Fma => widen_avx2(src, dst),
    }
}

/// The gemm register microkernel on the given backend:
/// `acc += Ap * Bp` over the shared `kc` dimension, `Ap` an `MR x kc`
/// panel (k-major), `Bp` a `kc x NR` panel (k-major).  Accumulation over
/// `p` is sequential per output element on both paths (f32 mul + add,
/// no FMA — module docs), so the paths are elementwise bit-identical.
#[inline]
pub fn microkernel_on(
    backend: Backend,
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    assert!(ap.len() >= kc * MR, "microkernel A panel too short");
    assert!(bp.len() >= kc * NR, "microkernel B panel too short");
    match backend {
        Backend::Scalar => scalar::microkernel(kc, ap, bp, acc),
        Backend::Avx2Fma => microkernel_avx2(kc, ap, bp, acc),
    }
}

/// [`microkernel_on`] with an explicit [`KernelTier`]: tier-0 routes to
/// the mul+add kernels above; tier-1 routes to the fused variants
/// ([`f32::mul_add`] scalar / `vfmadd231ps` AVX2).  Per output element
/// the accumulation over `p` is sequential on every (tier, backend)
/// combination — which is what keeps tile traversal and thread chunking
/// invisible in the bits even at tier-1.
#[inline]
pub fn microkernel_tier_on(
    backend: Backend,
    tier: KernelTier,
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    match tier {
        KernelTier::Deterministic => microkernel_on(backend, kc, ap, bp, acc),
        KernelTier::Fast => {
            assert!(ap.len() >= kc * MR, "microkernel A panel too short");
            assert!(bp.len() >= kc * NR, "microkernel B panel too short");
            match backend {
                Backend::Scalar => scalar::microkernel_fma(kc, ap, bp, acc),
                Backend::Avx2Fma => microkernel_fma_avx2(kc, ap, bp, acc),
            }
        }
    }
}

/// The wide (f64-accumulating) register microkernel on the given
/// backend: `out[i][j] = Σ_p Ap[i,p] · Bp[p,j]` over the **full** depth
/// `kc`, with the dot-product lane discipline (8 phase accumulators by
/// `p % 8`, the [`reduce_lanes`] tree, sequential `kc % 8` tail last).
/// `Ap` is an `MR x kc` packed panel (k-major, as laid out by
/// `blas::pack_a_strided`), `Bp` a `kc x NR` packed panel.  Overwrites
/// the tile — every element equals `dot_on(row_i, col_j)` bitwise, so
/// callers must pass the whole depth in one call (a `KC` split would
/// change the phase assignment).
#[inline]
pub fn microkernel_wide_on(
    backend: Backend,
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    out: &mut [[f64; NR]; MR],
) {
    assert!(ap.len() >= kc * MR, "wide microkernel A panel too short");
    assert!(bp.len() >= kc * NR, "wide microkernel B panel too short");
    match backend {
        Backend::Scalar => scalar::microkernel_wide(kc, ap, bp, out),
        Backend::Avx2Fma => microkernel_wide_avx2(kc, ap, bp, out),
    }
}

/// [`microkernel_wide_on`] with an explicit [`KernelTier`]: tier-0 is
/// the lane-disciplined f64 kernel above; tier-1 accumulates in *fused*
/// f32 (sequential over `p` per element, [`f32::mul_add`] scalar /
/// `vfmadd231ps` AVX2, both correctly rounded so the backends agree
/// bitwise within tier-1) and widens the finished sum into the f64
/// tile.  The consensus epoch loop always passes tier-0 — tier-1 here
/// exists for benches and tier experiments behind the same contract as
/// the f32 microkernel.
#[inline]
pub fn microkernel_wide_tier_on(
    backend: Backend,
    tier: KernelTier,
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    out: &mut [[f64; NR]; MR],
) {
    match tier {
        KernelTier::Deterministic => {
            microkernel_wide_on(backend, kc, ap, bp, out)
        }
        KernelTier::Fast => {
            assert!(ap.len() >= kc * MR, "wide microkernel A panel too short");
            assert!(bp.len() >= kc * NR, "wide microkernel B panel too short");
            match backend {
                Backend::Scalar => {
                    scalar::microkernel_wide_fma(kc, ap, bp, out)
                }
                Backend::Avx2Fma => {
                    microkernel_wide_fma_avx2(kc, ap, bp, out)
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64 trampolines: re-check CPU support so the pub `*_on` functions
// stay sound even if a caller passes `Backend::Avx2Fma` by hand on an
// unsupported machine (`is_x86_feature_detected!` caches, so the check
// is one relaxed atomic load), then enter the `unsafe` intrinsic body.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_avx2(x: &[f32], y: &[f32]) -> f64 {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: the assert above proves the CPU has AVX2+FMA, the only
    // precondition of the `#[target_feature]` callee; slices are read
    // in-bounds (it splits n = 8*(n/8) + n%8 itself).
    unsafe { avx2::dot(x, y) }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_wide_avx2(x: &[f64], y: &[f32]) -> f64 {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: AVX2+FMA verified by the assert above — the callee's only
    // precondition; all loads stay within the slice lengths it checks.
    unsafe { avx2::dot_wide(x, y) }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: AVX2+FMA verified by the assert above — the callee's only
    // precondition; it handles the x/y length mismatch check itself.
    unsafe { avx2::axpy(alpha, x, y) }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn widen_avx2(src: &[f32], dst: &mut [f64]) {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: AVX2+FMA verified by the assert above — the callee's only
    // precondition; src/dst bounds are asserted inside the callee.
    unsafe { avx2::widen(src, dst) }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn microkernel_avx2(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: AVX2+FMA verified by the assert above; the public `*_on`
    // wrapper has already asserted `ap`/`bp` cover kc*MR / kc*NR.
    unsafe { avx2::microkernel(kc, ap, bp, acc) }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn microkernel_fma_avx2(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: AVX2+FMA verified by the assert above; panel bounds
    // (kc*MR / kc*NR) were asserted by the tiered `*_on` entry point.
    unsafe { avx2::microkernel_fma(kc, ap, bp, acc) }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn microkernel_wide_avx2(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    out: &mut [[f64; NR]; MR],
) {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: AVX2+FMA verified by the assert above; panel bounds
    // (kc*MR / kc*NR) were asserted by the public `*_on` wrapper.
    unsafe { avx2::microkernel_wide(kc, ap, bp, out) }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn microkernel_wide_fma_avx2(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    out: &mut [[f64; NR]; MR],
) {
    assert!(avx2_available(), "avx2+fma kernels need avx2+fma support");
    // SAFETY: AVX2+FMA verified by the assert above; panel bounds
    // (kc*MR / kc*NR) were asserted by the tiered `*_on` entry point.
    unsafe { avx2::microkernel_wide_fma(kc, ap, bp, out) }
}

#[cfg(not(target_arch = "x86_64"))]
fn dot_avx2(_x: &[f32], _y: &[f32]) -> f64 {
    panic!("the avx2+fma kernel backend requires x86_64");
}

#[cfg(not(target_arch = "x86_64"))]
fn dot_wide_avx2(_x: &[f64], _y: &[f32]) -> f64 {
    panic!("the avx2+fma kernel backend requires x86_64");
}

#[cfg(not(target_arch = "x86_64"))]
fn axpy_avx2(_alpha: f32, _x: &[f32], _y: &mut [f32]) {
    panic!("the avx2+fma kernel backend requires x86_64");
}

#[cfg(not(target_arch = "x86_64"))]
fn widen_avx2(_src: &[f32], _dst: &mut [f64]) {
    panic!("the avx2+fma kernel backend requires x86_64");
}

#[cfg(not(target_arch = "x86_64"))]
fn microkernel_avx2(_kc: usize, _ap: &[f32], _bp: &[f32], _acc: &mut [[f32; NR]; MR]) {
    panic!("the avx2+fma kernel backend requires x86_64");
}

#[cfg(not(target_arch = "x86_64"))]
fn microkernel_fma_avx2(
    _kc: usize,
    _ap: &[f32],
    _bp: &[f32],
    _acc: &mut [[f32; NR]; MR],
) {
    panic!("the avx2+fma kernel backend requires x86_64");
}

#[cfg(not(target_arch = "x86_64"))]
fn microkernel_wide_avx2(
    _kc: usize,
    _ap: &[f32],
    _bp: &[f32],
    _out: &mut [[f64; NR]; MR],
) {
    panic!("the avx2+fma kernel backend requires x86_64");
}

#[cfg(not(target_arch = "x86_64"))]
fn microkernel_wide_fma_avx2(
    _kc: usize,
    _ap: &[f32],
    _bp: &[f32],
    _out: &mut [[f64; NR]; MR],
) {
    panic!("the avx2+fma kernel backend requires x86_64");
}

/// The shared horizontal reduction tree over the 8 f64 lane
/// accumulators — the scalar mirror of `vaddpd ymm(lo,hi)` followed by
/// the 128-bit fold (`vextractf128` + `vaddpd`) and the final scalar
/// add (`vunpckhpd` + `vaddsd`).  Both backends MUST reduce through
/// this exact association.
#[inline]
fn reduce_lanes(a: &[f64; LANES]) -> f64 {
    let s0 = a[0] + a[4];
    let s1 = a[1] + a[5];
    let s2 = a[2] + a[6];
    let s3 = a[3] + a[7];
    (s0 + s2) + (s1 + s3)
}

// ---------------------------------------------------------------------------
// Scalar fallbacks, restructured to the vector lane order.
// ---------------------------------------------------------------------------

mod scalar {
    use super::{reduce_lanes, LANES, MR, NR};

    /// 8 independent f64 accumulators in vector lane order, fixed
    /// reduction tree, sequential `n % 8` tail added last — the exact
    /// rounding sequence of `avx2::dot` (module docs).
    pub(super) fn dot(x: &[f32], y: &[f32]) -> f64 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc = [0.0f64; LANES];
        for c in 0..chunks {
            let base = c * LANES;
            for (l, a) in acc.iter_mut().enumerate() {
                // exact product (24-bit mantissas in f64), one rounding
                // at the add — the same single rounding the vector
                // path's fmadd performs
                *a += x[base + l] as f64 * y[base + l] as f64;
            }
        }
        let mut tail = 0.0f64;
        for i in chunks * LANES..n {
            tail += x[i] as f64 * y[i] as f64;
        }
        reduce_lanes(&acc) + tail
    }

    /// [`dot`] with a pre-widened left operand.  The product here is a
    /// full 53-bit x 24-bit f64 multiply (NOT exact in general), so both
    /// backends round it before the add — which also keeps this
    /// bit-identical to [`dot`] whenever `x[i] == x32[i] as f64`, since
    /// the widened product is exact and its rounding a no-op.
    pub(super) fn dot_wide(x: &[f64], y: &[f32]) -> f64 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc = [0.0f64; LANES];
        for c in 0..chunks {
            let base = c * LANES;
            for (l, a) in acc.iter_mut().enumerate() {
                *a += x[base + l] * y[base + l] as f64;
            }
        }
        let mut tail = 0.0f64;
        for i in chunks * LANES..n {
            tail += x[i] * y[i] as f64;
        }
        reduce_lanes(&acc) + tail
    }

    /// Elementwise, no reduction: lane structure is irrelevant to the
    /// bits, so the fallback keeps the obvious loop (round the product,
    /// round the add — exactly `vmulps` + `vaddps` per lane).
    pub(super) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// Elementwise exact conversion (f32 -> f64 is injective).
    pub(super) fn widen(src: &[f32], dst: &mut [f64]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s as f64;
        }
    }

    /// The register-tiled gemm inner kernel.  `acc[i]` is one 8-lane f32
    /// row; accumulation over `p` is sequential per element with
    /// mul-then-add rounding, matching `avx2::microkernel` lane for
    /// lane.  All indices are panel-local constant-trip loops, so LLVM
    /// keeps `acc` in vector registers even on this fallback path.
    pub(super) fn microkernel(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        acc: &mut [[f32; NR]; MR],
    ) {
        for p in 0..kc {
            let av = &ap[p * MR..p * MR + MR];
            let bv = &bp[p * NR..p * NR + NR];
            for (i, row) in acc.iter_mut().enumerate() {
                let ai = av[i];
                for (j, a) in row.iter_mut().enumerate() {
                    *a += ai * bv[j];
                }
            }
        }
    }

    /// The wide microkernel: per output element `(i, j)`, depth step
    /// `p` feeds f64 phase accumulator `p % 8` (products of widened f32
    /// are exact, one rounding at each add — the dot-product contract),
    /// phases fold through [`reduce_lanes`], the sequential `kc % 8`
    /// tail joins last, and the tile is *overwritten*.  Bit-identical
    /// to `dot(row_i(ap), col_j(bp))` per element.
    pub(super) fn microkernel_wide(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        out: &mut [[f64; NR]; MR],
    ) {
        let chunks = kc / LANES;
        for (i, row) in out.iter_mut().enumerate() {
            for (j, o) in row.iter_mut().enumerate() {
                let mut lanes = [0.0f64; LANES];
                for c in 0..chunks {
                    let base = c * LANES;
                    for (l, a) in lanes.iter_mut().enumerate() {
                        let p = base + l;
                        *a += ap[p * MR + i] as f64 * bp[p * NR + j] as f64;
                    }
                }
                let mut tail = 0.0f64;
                for p in chunks * LANES..kc {
                    tail += ap[p * MR + i] as f64 * bp[p * NR + j] as f64;
                }
                *o = reduce_lanes(&lanes) + tail;
            }
        }
    }

    /// The tier-1 wide microkernel: a single fused f32 accumulator per
    /// element, sequential over the full depth, widened exactly into
    /// the f64 tile at the end.  `f32::mul_add` is correctly rounded,
    /// so scalar and AVX2 tier-1 agree bitwise.
    pub(super) fn microkernel_wide_fma(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        out: &mut [[f64; NR]; MR],
    ) {
        for (i, row) in out.iter_mut().enumerate() {
            for (j, o) in row.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for p in 0..kc {
                    s = ap[p * MR + i].mul_add(bp[p * NR + j], s);
                }
                *o = s as f64;
            }
        }
    }

    /// The tier-1 microkernel: same traversal, fused rounding.
    /// `f32::mul_add` is IEEE correctly-rounded, so tier-1 scalar runs
    /// are reproducible regardless of whether LLVM lowers it to hardware
    /// `vfmadd` or libm `fmaf` — the within-backend bitwise promise
    /// holds on any host.
    pub(super) fn microkernel_fma(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        acc: &mut [[f32; NR]; MR],
    ) {
        for p in 0..kc {
            let av = &ap[p * MR..p * MR + MR];
            let bv = &bp[p * NR..p * NR + NR];
            for (i, row) in acc.iter_mut().enumerate() {
                let ai = av[i];
                for (j, a) in row.iter_mut().enumerate() {
                    *a = ai.mul_add(bv[j], *a);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA bodies.  `unsafe` is confined to these functions; every
// entry goes through the checked trampolines above.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{LANES, MR, NR};
    use std::arch::x86_64::*;

    /// Fold the two 4-lane f64 accumulators (lanes 0..=3 in `lo`,
    /// 4..=7 in `hi`) through the fixed tree of `super::reduce_lanes`.
    ///
    /// # Safety
    /// Requires AVX2 (checked by every public trampoline).
    // SAFETY: pure register arithmetic — no memory access; the AVX2
    // requirement is discharged by the trampolines' avx2_available()
    // assert before any caller reaches this module.
    #[target_feature(enable = "avx2")]
    unsafe fn reduce_pd(lo: __m256d, hi: __m256d) -> f64 {
        // [a0+a4, a1+a5, a2+a6, a3+a7]
        let s = _mm256_add_pd(lo, hi);
        let s_lo = _mm256_castpd256_pd128(s); // [s0, s1]
        let s_hi = _mm256_extractf128_pd::<1>(s); // [s2, s3]
        let t = _mm_add_pd(s_lo, s_hi); // [s0+s2, s1+s3]
        let t_hi = _mm_unpackhi_pd(t, t);
        _mm_cvtsd_f64(_mm_add_sd(t, t_hi)) // (s0+s2) + (s1+s3)
    }

    /// # Safety
    /// Requires AVX2+FMA and `x.len() == y.len()`.
    // SAFETY: every `loadu` reads 8 f32 at i = c*LANES with
    // c < n/LANES, so i+7 < n stays inside both slices; the remainder
    // uses checked indexing.  AVX2+FMA is asserted at the trampoline.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot(x: &[f32], y: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / LANES;
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        for c in 0..chunks {
            let i = c * LANES;
            let xv = _mm256_loadu_ps(xp.add(i));
            let yv = _mm256_loadu_ps(yp.add(i));
            let x_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(xv));
            let x_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(xv));
            let y_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(yv));
            let y_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(yv));
            // widened products are exact in f64, so the fused rounding
            // point equals mul-then-add — bit-identical to the scalar
            // fallback's `acc += x as f64 * y as f64`
            acc_lo = _mm256_fmadd_pd(x_lo, y_lo, acc_lo);
            acc_hi = _mm256_fmadd_pd(x_hi, y_hi, acc_hi);
        }
        let mut tail = 0.0f64;
        for i in chunks * LANES..n {
            tail += x[i] as f64 * y[i] as f64;
        }
        reduce_pd(acc_lo, acc_hi) + tail
    }

    /// # Safety
    /// Requires AVX2 and `x.len() == y.len()`.  Deliberately mul+add,
    /// not FMA: the f64 x f64 product is not exact, and the scalar
    /// contract rounds it before the accumulate.
    // SAFETY: unaligned loads read lanes i..i+7 (f32) and two f64
    // quads at i and i+4 with i+7 < n = min length; tail indexing is
    // bounds-checked.  AVX2 is asserted at the trampoline.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_wide(x: &[f64], y: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / LANES;
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        for c in 0..chunks {
            let i = c * LANES;
            let x_lo = _mm256_loadu_pd(xp.add(i));
            let x_hi = _mm256_loadu_pd(xp.add(i + 4));
            let yv = _mm256_loadu_ps(yp.add(i));
            let y_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(yv));
            let y_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(yv));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(x_lo, y_lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(x_hi, y_hi));
        }
        let mut tail = 0.0f64;
        for i in chunks * LANES..n {
            tail += x[i] * y[i] as f64;
        }
        reduce_pd(acc_lo, acc_hi) + tail
    }

    /// # Safety
    /// Requires AVX2 and `x.len() == y.len()`.  mul+add (no f32 FMA) so
    /// every lane rounds exactly like the scalar `*yi += alpha * xi`.
    // SAFETY: loads/stores touch y[i..i+8] and x[i..i+8] only for
    // i = c*LANES, c < n/LANES (in-bounds for both); `y` is borrowed
    // mutably so no aliasing.  AVX2 is asserted at the trampoline.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / LANES;
        let av = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            let xv = _mm256_loadu_ps(xp.add(i));
            let yv = _mm256_loadu_ps(yp.add(i));
            let r = _mm256_add_ps(yv, _mm256_mul_ps(av, xv));
            _mm256_storeu_ps(yp.add(i), r);
        }
        for i in chunks * LANES..n {
            y[i] += alpha * x[i];
        }
    }

    /// # Safety
    /// Requires AVX2 and `src.len() == dst.len()`.  Conversion is
    /// exact, so vectorization is trivially bit-identical.
    // SAFETY: reads src[i..i+8], writes dst[i..i+8] for i = c*LANES,
    // c < n/LANES — in-bounds on both sides; src/dst cannot alias
    // (&/&mut).  AVX2 is asserted at the trampoline.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn widen(src: &[f32], dst: &mut [f64]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len();
        let chunks = n / LANES;
        let sp = src.as_ptr();
        let dp = dst.as_mut_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            let sv = _mm256_loadu_ps(sp.add(i));
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(sv));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(sv));
            _mm256_storeu_pd(dp.add(i), lo);
            _mm256_storeu_pd(dp.add(i + 4), hi);
        }
        for i in chunks * LANES..n {
            dst[i] = src[i] as f64;
        }
    }

    /// # Safety
    /// Requires AVX2, `ap.len() >= kc * MR`, `bp.len() >= kc * NR`.
    ///
    /// One 8-lane f32 register per microtile row, broadcast A element,
    /// mul+add per `p` step — the same per-element rounding chain as
    /// the scalar microkernel (f32 FMA would round once where the
    /// contract rounds twice, so it is deliberately not used).
    // SAFETY: pointer reads stay below kc*MR (A) / kc*NR (B) — the
    // bounds the trampoline asserted; `acc` tile loads/stores are
    // fixed [MR][NR] arrays.  AVX2 is asserted at the trampoline.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn microkernel(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        acc: &mut [[f32; NR]; MR],
    ) {
        debug_assert!(ap.len() >= kc * MR);
        debug_assert!(bp.len() >= kc * NR);
        let a = ap.as_ptr();
        let b = bp.as_ptr();
        let mut c0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut c1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut c2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut c3 = _mm256_loadu_ps(acc[3].as_ptr());
        for p in 0..kc {
            let bv = _mm256_loadu_ps(b.add(p * NR));
            let ac = a.add(p * MR);
            c0 = _mm256_add_ps(c0, _mm256_mul_ps(_mm256_set1_ps(*ac), bv));
            c1 = _mm256_add_ps(c1, _mm256_mul_ps(_mm256_set1_ps(*ac.add(1)), bv));
            c2 = _mm256_add_ps(c2, _mm256_mul_ps(_mm256_set1_ps(*ac.add(2)), bv));
            c3 = _mm256_add_ps(c3, _mm256_mul_ps(_mm256_set1_ps(*ac.add(3)), bv));
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    }

    /// Tier-1 microkernel: `vfmadd231ps` fuses the multiply and add into
    /// one rounding per element.  Same traversal order as the tier-0
    /// kernel, so within-backend runs stay bitwise-reproducible; only the
    /// per-element rounding differs from tier-0 (validated by tolerance).
    ///
    /// # Safety
    /// Requires AVX2 + FMA; `ap`/`bp` must hold at least `kc * MR` /
    /// `kc * NR` elements (asserted by the dispatching trampoline).
    // SAFETY: identical access pattern to the tier-0 microkernel above
    // (reads below kc*MR / kc*NR, fixed-size acc tile); AVX2+FMA is
    // asserted at the trampoline.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn microkernel_fma(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        acc: &mut [[f32; NR]; MR],
    ) {
        debug_assert!(ap.len() >= kc * MR);
        debug_assert!(bp.len() >= kc * NR);
        let a = ap.as_ptr();
        let b = bp.as_ptr();
        let mut c0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut c1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut c2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut c3 = _mm256_loadu_ps(acc[3].as_ptr());
        for p in 0..kc {
            let bv = _mm256_loadu_ps(b.add(p * NR));
            let ac = a.add(p * MR);
            c0 = _mm256_fmadd_ps(_mm256_set1_ps(*ac), bv, c0);
            c1 = _mm256_fmadd_ps(_mm256_set1_ps(*ac.add(1)), bv, c1);
            c2 = _mm256_fmadd_ps(_mm256_set1_ps(*ac.add(2)), bv, c2);
            c3 = _mm256_fmadd_ps(_mm256_set1_ps(*ac.add(3)), bv, c3);
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    }

    /// The wide (f64) microkernel.  Per output row the 8 depth phases
    /// are kept as 8 vector accumulators over one 4-column half of the
    /// tile (two passes per row keep the register count at 8 + temps);
    /// `vfmadd231pd` on widened-f32 products is exact-equivalent to
    /// mul-then-add, so each phase performs the identical rounding
    /// sequence as the scalar lanes, and the phase fold below is the
    /// vectorized `super::reduce_lanes` tree.
    ///
    /// # Safety
    /// Requires AVX2 + FMA; `ap`/`bp` must hold at least `kc * MR` /
    /// `kc * NR` elements (asserted by the dispatching trampoline).
    // SAFETY: depth index p < kc throughout, so A reads (p*MR + i,
    // i < MR) and B reads of 4 f32 at p*NR + col0 (col0 <= 4) stay
    // below kc*MR / kc*NR; the f64 tile is a fixed [MR][NR] array.
    // AVX2+FMA is asserted at the trampoline.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn microkernel_wide(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        out: &mut [[f64; NR]; MR],
    ) {
        debug_assert!(ap.len() >= kc * MR);
        debug_assert!(bp.len() >= kc * NR);
        let chunks = kc / LANES;
        let a = ap.as_ptr();
        let b = bp.as_ptr();
        for (i, row) in out.iter_mut().enumerate() {
            for half in 0..2 {
                let col0 = half * 4;
                let mut ph = [_mm256_setzero_pd(); LANES];
                for c in 0..chunks {
                    let base = c * LANES;
                    for (l, acc) in ph.iter_mut().enumerate() {
                        let p = base + l;
                        let av = _mm256_set1_pd(*a.add(p * MR + i) as f64);
                        let bv = _mm256_cvtps_pd(_mm_loadu_ps(
                            b.add(p * NR + col0),
                        ));
                        *acc = _mm256_fmadd_pd(av, bv, *acc);
                    }
                }
                // the reduce_lanes tree, 4 columns at a time:
                // ((p0+p4)+(p2+p6)) + ((p1+p5)+(p3+p7))
                let s0 = _mm256_add_pd(ph[0], ph[4]);
                let s1 = _mm256_add_pd(ph[1], ph[5]);
                let s2 = _mm256_add_pd(ph[2], ph[6]);
                let s3 = _mm256_add_pd(ph[3], ph[7]);
                let red = _mm256_add_pd(
                    _mm256_add_pd(s0, s2),
                    _mm256_add_pd(s1, s3),
                );
                let mut reds = [0.0f64; 4];
                _mm256_storeu_pd(reds.as_mut_ptr(), red);
                for (jj, &r) in reds.iter().enumerate() {
                    let j = col0 + jj;
                    let mut tail = 0.0f64;
                    for p in chunks * LANES..kc {
                        tail += *a.add(p * MR + i) as f64
                            * *b.add(p * NR + j) as f64;
                    }
                    row[j] = r + tail;
                }
            }
        }
    }

    /// Tier-1 wide microkernel: one fused f32 accumulator vector per
    /// row, sequential over the full depth (the same per-element order
    /// as the scalar twin, both correctly rounded), widened exactly
    /// into the f64 tile at the end.
    ///
    /// # Safety
    /// Requires AVX2 + FMA; `ap`/`bp` must hold at least `kc * MR` /
    /// `kc * NR` elements (asserted by the dispatching trampoline).
    // SAFETY: reads A at p*MR + i (p < kc, i < MR) and 8 f32 of B at
    // p*NR — within the asserted panel bounds; stores hit the fixed
    // [MR][NR] f64 tile.  AVX2+FMA is asserted at the trampoline.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn microkernel_wide_fma(
        kc: usize,
        ap: &[f32],
        bp: &[f32],
        out: &mut [[f64; NR]; MR],
    ) {
        debug_assert!(ap.len() >= kc * MR);
        debug_assert!(bp.len() >= kc * NR);
        let a = ap.as_ptr();
        let b = bp.as_ptr();
        for (i, row) in out.iter_mut().enumerate() {
            let mut cv = _mm256_setzero_ps();
            for p in 0..kc {
                let av = _mm256_set1_ps(*a.add(p * MR + i));
                let bv = _mm256_loadu_ps(b.add(p * NR));
                cv = _mm256_fmadd_ps(av, bv, cv);
            }
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(cv));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(cv));
            _mm256_storeu_pd(row.as_mut_ptr(), lo);
            _mm256_storeu_pd(row.as_mut_ptr().add(4), hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_rule() {
        // forcing scalar always wins, even with avx2 present
        assert_eq!(select(true, true), Backend::Scalar);
        assert_eq!(select(true, false), Backend::Scalar);
        // otherwise the hardware decides
        assert_eq!(select(false, true), Backend::Avx2Fma);
        assert_eq!(select(false, false), Backend::Scalar);
    }

    #[test]
    fn active_is_stable_and_consistent_with_env() {
        let first = active();
        // cached: repeated queries can never flip mid-process
        assert_eq!(active(), first);
        let forced = force_scalar_env();
        if forced {
            assert_eq!(first, Backend::Scalar);
        }
        if !avx2_available() {
            assert_eq!(first, Backend::Scalar);
        }
        // description never panics and names the backend family
        let d = description();
        assert!(d.starts_with("scalar") || d.starts_with("avx2"));
    }

    #[test]
    fn reduce_tree_association() {
        // the tree is ((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7)) — check with
        // magnitudes that would expose a different association
        let a = [1e16, 1.0, -1e16, 2.0, 3.0, 4.0, 5.0, 6.0];
        let expect = ((1e16 + 3.0) + (-1e16 + 5.0)) + ((1.0 + 4.0) + (2.0 + 6.0));
        assert_eq!(reduce_lanes(&a).to_bits(), expect.to_bits());
    }

    #[test]
    fn scalar_dot_matches_sequential_within_rounding() {
        // the lane restructure changes the association, not the math:
        // against a sequential f64 reference the error stays at rounding
        // noise for benign data
        let x: Vec<f32> = (0..1000).map(|i| ((i * 37) % 101) as f32 * 0.01 - 0.5).collect();
        let y: Vec<f32> = (0..1000).map(|i| ((i * 53) % 97) as f32 * 0.02 - 1.0).collect();
        let mut seq = 0.0f64;
        for (a, b) in x.iter().zip(&y) {
            seq += *a as f64 * *b as f64;
        }
        let lane = dot_on(Backend::Scalar, &x, &y);
        assert!((lane - seq).abs() <= 1e-9 * seq.abs().max(1.0));
    }

    #[test]
    fn lane_empty_and_tiny_inputs() {
        assert_eq!(dot_on(Backend::Scalar, &[], &[]), 0.0);
        assert_eq!(dot_on(Backend::Scalar, &[2.0], &[3.0]), 6.0);
        let mut d = [0.0f64; 3];
        widen_on(Backend::Scalar, &[1.0, -2.5, 0.5], &mut d);
        assert_eq!(d, [1.0, -2.5, 0.5]);
    }

    #[test]
    #[should_panic]
    fn dot_on_length_mismatch_panics_in_release_too() {
        let _ = dot_on(Backend::Scalar, &[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn tier_selection_rule() {
        assert_eq!(select_tier(false), KernelTier::Deterministic);
        assert_eq!(select_tier(true), KernelTier::Fast);
        // the default tier is the deterministic one
        assert_eq!(KernelTier::default(), KernelTier::Deterministic);
        assert_eq!(KernelTier::Deterministic.name(), "deterministic");
        assert_eq!(KernelTier::Fast.name(), "fast");
    }

    #[test]
    fn active_tier_is_stable_and_consistent_with_env() {
        let first = active_tier();
        // cached: repeated queries can never flip mid-process
        assert_eq!(active_tier(), first);
        let fast = crate::config::envvars::fast_tier();
        assert_eq!(first, select_tier(fast));
        // description never panics and names the tier
        assert!(tier_description().starts_with("tier-"));
    }

    #[test]
    fn tier0_entry_is_the_tier0_kernel_bitwise() {
        // microkernel_tier_on at tier-0 must be byte-for-byte the tier-0
        // kernel, whatever the process env says
        let kc = 37;
        let ap: Vec<f32> = (0..kc * MR).map(|i| ((i * 29) % 23) as f32 * 0.06 - 0.7).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| ((i * 31) % 19) as f32 * 0.05 - 0.4).collect();
        let mut a0 = [[0.1f32; NR]; MR];
        let mut a1 = [[0.1f32; NR]; MR];
        microkernel_on(Backend::Scalar, kc, &ap, &bp, &mut a0);
        microkernel_tier_on(
            Backend::Scalar,
            KernelTier::Deterministic,
            kc,
            &ap,
            &bp,
            &mut a1,
        );
        assert_eq!(a0.map(|r| r.map(f32::to_bits)), a1.map(|r| r.map(f32::to_bits)));
    }

    #[test]
    fn tier1_scalar_is_reproducible_and_close_to_tier0() {
        let kc = 64;
        let ap: Vec<f32> = (0..kc * MR).map(|i| ((i * 41) % 27) as f32 * 0.04 - 0.5).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| ((i * 43) % 31) as f32 * 0.03 - 0.45).collect();
        let mut t0 = [[0.0f32; NR]; MR];
        let mut f1 = [[0.0f32; NR]; MR];
        let mut f2 = [[0.0f32; NR]; MR];
        microkernel_tier_on(
            Backend::Scalar,
            KernelTier::Deterministic,
            kc,
            &ap,
            &bp,
            &mut t0,
        );
        microkernel_tier_on(Backend::Scalar, KernelTier::Fast, kc, &ap, &bp, &mut f1);
        microkernel_tier_on(Backend::Scalar, KernelTier::Fast, kc, &ap, &bp, &mut f2);
        // within-backend tier-1 runs are bitwise-identical
        assert_eq!(f1.map(|r| r.map(f32::to_bits)), f2.map(|r| r.map(f32::to_bits)));
        // fused rounding drops at most one rounding per element per step:
        // stays within a small multiple of f32 eps of the tier-0 result
        for (r0, r1) in t0.iter().zip(&f1) {
            for (v0, v1) in r0.iter().zip(r1) {
                let tol = 2.0 * kc as f32 * f32::EPSILON * v0.abs().max(1.0);
                assert!((v0 - v1).abs() <= tol, "{v0} vs {v1}");
            }
        }
    }

    /// Gather row `i` of a packed A panel / column `j` of a packed B
    /// panel back into contiguous vectors for the dot oracle.
    fn gather(ap: &[f32], bp: &[f32], kc: usize, i: usize, j: usize) -> (Vec<f32>, Vec<f32>) {
        let row: Vec<f32> = (0..kc).map(|p| ap[p * MR + i]).collect();
        let col: Vec<f32> = (0..kc).map(|p| bp[p * NR + j]).collect();
        (row, col)
    }

    #[test]
    fn wide_kernel_is_per_element_dot_bitwise_every_remainder_class() {
        // kc sweeps every kc % 8 class; every backend must reproduce
        // dot() bit-for-bit in every tile element
        for kc in [0usize, 1, 3, 7, 8, 9, 13, 16, 29, 64, 67] {
            let ap: Vec<f32> = (0..kc.max(1) * MR)
                .map(|i| ((i * 29) % 23) as f32 * 0.06 - 0.7)
                .collect();
            let bp: Vec<f32> = (0..kc.max(1) * NR)
                .map(|i| ((i * 31) % 19) as f32 * 0.05 - 0.4)
                .collect();
            for &b in &available() {
                let mut out = [[1.5f64; NR]; MR]; // must be overwritten
                microkernel_wide_on(b, kc, &ap, &bp, &mut out);
                for i in 0..MR {
                    for j in 0..NR {
                        let (row, col) = gather(&ap, &bp, kc, i, j);
                        let want = dot_on(Backend::Scalar, &row, &col);
                        assert_eq!(
                            out[i][j].to_bits(),
                            want.to_bits(),
                            "kc={kc} i={i} j={j} backend={}",
                            b.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn wide_tier0_entry_is_the_wide_kernel_bitwise() {
        let kc = 21;
        let ap: Vec<f32> = (0..kc * MR).map(|i| ((i * 37) % 17) as f32 * 0.07 - 0.5).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| ((i * 41) % 13) as f32 * 0.04 - 0.3).collect();
        let mut o0 = [[0.0f64; NR]; MR];
        let mut o1 = [[0.0f64; NR]; MR];
        microkernel_wide_on(Backend::Scalar, kc, &ap, &bp, &mut o0);
        microkernel_wide_tier_on(
            Backend::Scalar,
            KernelTier::Deterministic,
            kc,
            &ap,
            &bp,
            &mut o1,
        );
        assert_eq!(o0.map(|r| r.map(f64::to_bits)), o1.map(|r| r.map(f64::to_bits)));
    }

    #[test]
    fn wide_tier1_is_reproducible_and_close_to_tier0() {
        let kc = 48;
        let ap: Vec<f32> = (0..kc * MR).map(|i| ((i * 43) % 29) as f32 * 0.05 - 0.6).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| ((i * 47) % 31) as f32 * 0.03 - 0.4).collect();
        let mut t0 = [[0.0f64; NR]; MR];
        microkernel_wide_tier_on(
            Backend::Scalar,
            KernelTier::Deterministic,
            kc,
            &ap,
            &bp,
            &mut t0,
        );
        let mut runs = Vec::new();
        for &b in &available() {
            let mut f = [[0.0f64; NR]; MR];
            microkernel_wide_tier_on(Backend::Scalar, KernelTier::Fast, kc, &ap, &bp, &mut f);
            let mut g = [[0.0f64; NR]; MR];
            microkernel_wide_tier_on(b, KernelTier::Fast, kc, &ap, &bp, &mut g);
            // within tier-1 every backend fuses identically
            assert_eq!(f.map(|r| r.map(f64::to_bits)), g.map(|r| r.map(f64::to_bits)));
            runs.push(f);
        }
        for (r0, r1) in t0.iter().zip(&runs[0]) {
            for (v0, v1) in r0.iter().zip(r1) {
                let tol = 4.0 * kc as f64 * f32::EPSILON as f64 * v0.abs().max(1.0);
                assert!((v0 - v1).abs() <= tol, "{v0} vs {v1}");
            }
        }
    }

    #[test]
    fn wide_kernel_propagates_nan() {
        let kc = 11;
        let mut ap: Vec<f32> = vec![0.5; kc * MR];
        let bp: Vec<f32> = vec![0.25; kc * NR];
        ap[3 * MR + 1] = f32::NAN; // depth 3, row 1
        for &b in &available() {
            let mut out = [[0.0f64; NR]; MR];
            microkernel_wide_on(b, kc, &ap, &bp, &mut out);
            for (i, row) in out.iter().enumerate() {
                for &v in row {
                    if i == 1 {
                        assert!(v.is_nan(), "backend {}", b.name());
                    } else {
                        assert!(!v.is_nan(), "backend {}", b.name());
                    }
                }
            }
        }
    }

    #[test]
    fn tier1_backends_agree_to_tolerance() {
        if !avx2_available() {
            return;
        }
        let kc = 96;
        let ap: Vec<f32> = (0..kc * MR).map(|i| ((i * 17) % 13) as f32 * 0.08 - 0.5).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| ((i * 19) % 11) as f32 * 0.09 - 0.5).collect();
        let mut s = [[0.25f32; NR]; MR];
        let mut v = [[0.25f32; NR]; MR];
        microkernel_tier_on(Backend::Scalar, KernelTier::Fast, kc, &ap, &bp, &mut s);
        microkernel_tier_on(Backend::Avx2Fma, KernelTier::Fast, kc, &ap, &bp, &mut v);
        // both fuse every step identically (correctly-rounded fma), so in
        // fact they agree bitwise — assert the stronger property
        assert_eq!(s.map(|r| r.map(f32::to_bits)), v.map(|r| r.map(f32::to_bits)));
    }
}
