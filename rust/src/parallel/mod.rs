//! Parallel execution subsystem for the native compute path.
//!
//! The paper's Algorithm 1 is embarrassingly parallel across partitions —
//! every eq. (6) update touches only its own `(x_j, P_j)` — yet the
//! reference [`crate::solver::NativeEngine`] executes partitions
//! serially.  This module supplies the missing substrate:
//!
//! * [`pool`] — a persistent, std-only scoped thread pool (no rayon /
//!   crossbeam offline); workers live as long as the engine, scopes let
//!   jobs borrow partition state without `'static` gymnastics;
//! * [`engine`] — [`ParallelEngine`], a [`crate::solver::ComputeEngine`]
//!   that fans the per-partition updates, the eq. (7) reduction, worker
//!   init and the DGD forward product out over the pool while producing
//!   *bit-identical* iterates to the sequential engine at any thread
//!   count (see the determinism notes on each method).
//!
//! `benches/parallel_scaling.rs` measures the speedup over the
//! sequential engine at J ∈ {2, 4, 8}.

pub mod engine;
pub mod pool;

pub use engine::ParallelEngine;
pub use pool::{default_threads, Scope, ThreadPool};
