//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.  `manifest.json` lists every AOT-lowered graph with its
//! parameters and I/O signature; this module parses and indexes it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::json::Json;
use crate::error::{DapcError, Result};

/// Metadata for one compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// Path to the `.hlo.txt` file (absolute, resolved against the
    /// manifest directory).
    pub path: PathBuf,
    /// Graph kind: init_qr | init_classical | init_fat | update | average
    /// | round | solve | dgd_grad | mse.
    pub kind: String,
    /// Shape parameters (j, l, n — whichever apply to the kind).
    pub params: BTreeMap<String, usize>,
    /// Input shapes in call order.
    pub input_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.get(key).copied()
    }
}

/// Indexed view over all artifacts in a directory.
#[derive(Debug, Default)]
pub struct ArtifactManifest {
    by_name: BTreeMap<String, ArtifactMeta>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            DapcError::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                mpath.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON with paths resolved against `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let root = Json::parse(text)?;
        let arr = root.as_arr().ok_or_else(|| {
            DapcError::Artifact("manifest must be a JSON array".into())
        })?;
        let mut by_name = BTreeMap::new();
        for entry in arr {
            let name = entry.req_str("name")?.to_string();
            let file = entry.req_str("file")?;
            let params_json = entry.get("params").ok_or_else(|| {
                DapcError::Artifact(format!("{name}: missing params"))
            })?;
            let kind = params_json.req_str("kind")?.to_string();
            let mut params = BTreeMap::new();
            for (k, v) in params_json.as_obj().unwrap() {
                if let Some(u) = v.as_usize() {
                    params.insert(k.clone(), u);
                }
            }
            let mut input_shapes = Vec::new();
            if let Some(inputs) = entry.get("inputs").and_then(Json::as_arr) {
                for inp in inputs {
                    let shape = inp
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| {
                            dims.iter().filter_map(Json::as_usize).collect()
                        })
                        .unwrap_or_default();
                    input_shapes.push(shape);
                }
            }
            by_name.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    path: dir.join(file),
                    kind,
                    params,
                    input_shapes,
                },
            );
        }
        Ok(Self { by_name })
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name.get(name).ok_or_else(|| {
            DapcError::Artifact(format!(
                "artifact {name:?} not in manifest; available: {:?}",
                self.names().collect::<Vec<_>>()
            ))
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(String::as_str)
    }

    /// All artifacts of a given kind.
    pub fn of_kind<'a>(
        &'a self,
        kind: &'a str,
    ) -> impl Iterator<Item = &'a ArtifactMeta> {
        self.by_name.values().filter(move |m| m.kind == kind)
    }

    /// Available (l, n) buckets for a given init kind — feeds
    /// `partition::bucket::choose_bucket`.
    pub fn init_buckets(&self, kind: &str) -> Vec<(usize, usize)> {
        self.of_kind(kind)
            .filter_map(|m| Some((m.param("l")?, m.param("n")?)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"[
      {"name": "init_qr_l64_n32", "file": "init_qr_l64_n32.hlo.txt",
       "params": {"kind": "init_qr", "l": 64, "n": 32},
       "inputs": [{"shape": [64, 32], "dtype": "float32"},
                   {"shape": [64], "dtype": "float32"}],
       "outputs": [{"shape": [32]}, {"shape": [32, 32]}]},
      {"name": "update_n32", "file": "update_n32.hlo.txt",
       "params": {"kind": "update", "n": 32},
       "inputs": [{"shape": [32]}, {"shape": [32]},
                   {"shape": [32, 32]}, {"shape": []}]}
    ]"#;

    #[test]
    fn parse_and_lookup() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.len(), 2);
        let init = m.get("init_qr_l64_n32").unwrap();
        assert_eq!(init.kind, "init_qr");
        assert_eq!(init.param("l"), Some(64));
        assert_eq!(init.param("n"), Some(32));
        assert_eq!(init.path, Path::new("/tmp/a/init_qr_l64_n32.hlo.txt"));
        assert_eq!(init.input_shapes, vec![vec![64, 32], vec![64]]);
        // scalar input has empty shape
        let upd = m.get("update_n32").unwrap();
        assert_eq!(upd.input_shapes[3], Vec::<usize>::new());
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new(".")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("update_n32"), "{err}");
    }

    #[test]
    fn kind_filter_and_buckets() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert_eq!(m.of_kind("init_qr").count(), 1);
        assert_eq!(m.init_buckets("init_qr"), vec![(64, 32)]);
        assert!(m.init_buckets("init_fat").is_empty());
    }

    #[test]
    fn malformed_rejected() {
        assert!(ArtifactManifest::parse("{}", Path::new(".")).is_err());
        assert!(ArtifactManifest::parse(
            r#"[{"name": "x"}]"#,
            Path::new(".")
        )
        .is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Exercises the actual artifacts/ directory when present (built by
        // `make artifacts`); skipped otherwise so unit tests stay hermetic.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            assert!(m.contains("update_n32"));
            assert!(m.get("round_j2_n128").unwrap().path.exists());
        }
    }
}
