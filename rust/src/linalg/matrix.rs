//! Row-major dense f32 matrix used throughout the native engine.

use std::fmt;

/// Dense row-major matrix of `f32`.
///
/// f32 matches the artifact dtype so native and XLA engines are
/// bit-comparable; accumulations inside the kernels use f64 where it
/// matters (norms, reductions).
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major vec. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix data length mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the underlying row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let s = i * self.cols;
        &self.data[s..s + self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let s = i * self.cols;
        &mut self.data[s..s + self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Rows `[start, end)` as a new matrix (the paper's
    /// `create_submatrices` slicing).
    pub fn slice_rows(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows);
        Matrix {
            rows: end - start,
            cols: self.cols,
            data: self.data[start * self.cols..end * self.cols].to_vec(),
        }
    }

    /// Vertically stack two matrices with equal column counts.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Pad with zero rows up to `rows` (exact for QR — see DESIGN.md §3).
    pub fn pad_rows(&self, rows: usize) -> Matrix {
        assert!(rows >= self.rows);
        let mut data = self.data.clone();
        data.resize(rows * self.cols, 0.0);
        Matrix { rows, cols: self.cols, data }
    }

    /// Block-diagonal extension: append `k` extra columns and `k` extra
    /// rows holding an identity block (exact n-padding — DESIGN.md §3).
    pub fn pad_block_identity(&self, k: usize) -> Matrix {
        let (r, c) = (self.rows, self.cols);
        let mut out = Matrix::zeros(r + k, c + k);
        for i in 0..r {
            out.as_mut_slice()[i * (c + k)..i * (c + k) + c]
                .copy_from_slice(self.row(i));
        }
        for i in 0..k {
            out[(r + i, c + i)] = 1.0;
        }
        out
    }

    /// Frobenius norm (f64 accumulation).
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij| between two matrices of equal shape.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let vals: Vec<String> =
                (0..cols).map(|j| format!("{:>10.4}", self[(i, j)])).collect();
            writeln!(f, "  [{}{}]", vals.join(", "), if self.cols > 8 { ", …" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1)[2], 5.0);
        assert_eq!(m.col(2), vec![0.0, 5.0, 0.0]);
    }

    #[test]
    fn eye_and_transpose() {
        let e = Matrix::eye(4);
        assert_eq!(e.transpose(), e);
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t[(2, 1)], m[(1, 2)]);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn slice_and_stack_roundtrip() {
        let m = Matrix::from_fn(6, 2, |i, j| (i * 2 + j) as f32);
        let top = m.slice_rows(0, 3);
        let bot = m.slice_rows(3, 6);
        assert_eq!(top.vstack(&bot), m);
    }

    #[test]
    fn pad_rows_appends_zeros() {
        let m = Matrix::from_fn(2, 2, |i, j| (i + j) as f32 + 1.0);
        let p = m.pad_rows(4);
        assert_eq!(p.shape(), (4, 2));
        assert_eq!(p.row(3), &[0.0, 0.0]);
        assert_eq!(p.slice_rows(0, 2), m);
    }

    #[test]
    fn pad_block_identity_structure() {
        let m = Matrix::from_fn(3, 2, |_, _| 2.0);
        let p = m.pad_block_identity(2);
        assert_eq!(p.shape(), (5, 4));
        assert_eq!(p[(3, 2)], 1.0);
        assert_eq!(p[(4, 3)], 1.0);
        assert_eq!(p[(3, 3)], 0.0);
        assert_eq!(p[(0, 2)], 0.0);
        assert_eq!(p[(0, 0)], 2.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_length_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 5]);
    }
}
