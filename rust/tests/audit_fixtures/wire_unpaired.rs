// Seeded violation: a Message enum whose `Pong` variant is encoded but
// never decoded — a frame the peer can emit and nobody can read.
// Scanned under the pretend path rust/src/coordinator/message.rs.
pub enum Message {
    Ping,
    Pong,
}

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Message::Ping => vec![0],
            Message::Pong => vec![1],
        }
    }

    pub fn decode(buf: &[u8]) -> Option<Message> {
        match buf.first()? {
            0 => Some(Message::Ping),
            _ => None,
        }
    }
}
