//! Householder QR factorization (paper §2, eq. (1)).
//!
//! Reduced (economy) form `A = Q1 R` for tall `A` (l x n, l >= n): `Q1` is
//! (l x n) with orthonormal columns, `R` is (n x n) upper triangular.  This
//! is the native-engine twin of `kernels/linalg.py::householder_qr` — the
//! decomposed-APC init is built on it.

use super::{blas, Matrix};

/// Result of a reduced QR factorization.
pub struct QrFactors {
    /// (l x n) semi-orthogonal factor.
    pub q1: Matrix,
    /// (n x n) upper-triangular factor.
    pub r: Matrix,
}

/// Reduced Householder QR of a tall matrix (l >= n).
///
/// Reflectors are accumulated in-place over a working copy of A; `Q1` is
/// recovered by applying them in reverse to the first n identity columns.
pub fn householder_qr(a: &Matrix) -> QrFactors {
    let (l, n) = a.shape();
    assert!(l >= n, "householder_qr requires a tall matrix, got {l}x{n}");
    let mut work = a.clone();
    // reflector k lives in vs[k*l .. (k+1)*l]
    let mut vs = vec![0.0f32; n * l];

    for k in 0..n {
        // v = masked column k of work (rows >= k)
        let v = &mut vs[k * l..(k + 1) * l];
        for i in k..l {
            v[i] = work[(i, k)];
        }
        let sigma = blas::dot(&v[k..], &v[k..]).sqrt();
        if sigma == 0.0 {
            // zero column below k: null reflector, leave v = 0
            v.fill(0.0);
            continue;
        }
        let alpha = if v[k] >= 0.0 { -sigma } else { sigma } as f32;
        v[k] -= alpha;
        let vnorm = blas::dot(&v[k..], &v[k..]).sqrt();
        if vnorm < 1e-30 {
            v.fill(0.0);
            continue;
        }
        let inv = (1.0 / vnorm) as f32;
        for vi in v[k..].iter_mut() {
            *vi *= inv;
        }
        // work <- work - 2 v (v^T work); only rows >= k, cols >= k matter
        // (cols < k are already triangularized: zero below row k).
        apply_reflector_left(&mut work, v, k, k);
    }

    // R = upper triangle of the first n rows.
    let mut r = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r[(i, j)] = work[(i, j)];
        }
    }

    // Q1 = H_0 ... H_{n-1} E, E = first n columns of I_l.
    let mut q1 = Matrix::from_fn(l, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for k in (0..n).rev() {
        let v = &vs[k * l..(k + 1) * l];
        // Applying H_{n-1}..H_k to E leaves columns < k untouched (they
        // are still e_c with support above row k, where v is zero), so the
        // update can be restricted to cols >= k — this halves the
        // Q1-recovery cost (§Perf).
        apply_reflector_left(&mut q1, v, k, k);
    }
    QrFactors { q1, r }
}

/// `m[:, col_start..] <- (I - 2 v v^T) m[:, col_start..]`, skipping the
/// first `k` rows where v is zero.  Callers guarantee that columns before
/// `col_start` would be unchanged (their v-weighted sums are zero).
fn apply_reflector_left(m: &mut Matrix, v: &[f32], k: usize, col_start: usize) {
    let (rows, cols) = m.shape();
    debug_assert_eq!(v.len(), rows);
    // w = m[:, col_start..]^T v, then m[:, col_start..] -= 2 v w^T
    let mut w = vec![0.0f32; cols - col_start];
    for i in k..rows {
        let vi = v[i];
        if vi != 0.0 {
            blas::axpy(vi, &m.row(i)[col_start..], &mut w);
        }
    }
    for i in k..rows {
        let c = -2.0 * v[i];
        if c != 0.0 {
            blas::axpy(c, &w, &mut m.row_mut(i)[col_start..]);
        }
    }
}

/// Apply `Q1^T` to a vector of length l, returning length-n `Q1^T b`.
pub fn qt_mul(f: &QrFactors, b: &[f32]) -> Vec<f32> {
    let n = f.r.cols();
    let mut out = vec![0.0f32; n];
    blas::gemv_t(&f.q1, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm, gemm_tn};
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    #[test]
    fn reconstruction() {
        for &(l, n) in &[(4, 4), (16, 8), (64, 32), (33, 7), (100, 100)] {
            let a = randm(l, n, l as u64 * 31 + n as u64);
            let f = householder_qr(&a);
            let recon = gemm(&f.q1, &f.r);
            assert!(recon.max_abs_diff(&a) < 5e-4, "({l},{n})");
        }
    }

    #[test]
    fn orthonormal_columns() {
        let a = randm(48, 20, 7);
        let f = householder_qr(&a);
        let qtq = gemm_tn(&f.q1, &f.q1);
        assert!(qtq.max_abs_diff(&Matrix::eye(20)) < 5e-5);
    }

    #[test]
    fn r_upper_triangular() {
        let a = randm(30, 12, 9);
        let f = householder_qr(&a);
        for i in 0..12 {
            for j in 0..i {
                assert_eq!(f.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn zero_column_no_nan() {
        let mut a = Matrix::zeros(10, 4);
        for i in 0..10 {
            a[(i, 0)] = 1.0;
            a[(i, 2)] = i as f32;
        }
        let f = householder_qr(&a);
        assert!(f.q1.as_slice().iter().all(|v| v.is_finite()));
        assert!(f.r.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn padded_rows_leave_r_and_qtb_unchanged() {
        // QR([A; 0]) must produce the same R and the same Q1^T [b; 0] —
        // this is what makes shape-bucket padding exact (DESIGN.md §3).
        let a = randm(20, 8, 13);
        let mut g = seeded(14);
        let b: Vec<f32> = (0..20).map(|_| g.normal_f32()).collect();
        let f = householder_qr(&a);
        let ap = a.pad_rows(32);
        let mut bp = b.clone();
        bp.resize(32, 0.0);
        let fp = householder_qr(&ap);
        // R unique up to sign of rows; our sign convention is deterministic
        assert!(f.r.max_abs_diff(&fp.r) < 1e-4);
        let qtb = qt_mul(&f, &b);
        let qtbp = qt_mul(&fp, &bp);
        for i in 0..8 {
            assert!((qtb[i] - qtbp[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn property_random_shapes() {
        // hand-rolled property sweep (no proptest offline)
        let mut g = seeded(99);
        for case in 0..25 {
            let n = g.gen_range(1, 24);
            let l = n + g.gen_range(0, 24);
            let a = randm(l, n, 1000 + case);
            let f = householder_qr(&a);
            assert!(gemm(&f.q1, &f.r).max_abs_diff(&a) < 2e-3, "case {case} ({l},{n})");
            let qtq = gemm_tn(&f.q1, &f.q1);
            assert!(qtq.max_abs_diff(&Matrix::eye(n)) < 2e-3, "case {case}");
        }
    }
}
