//! Export surfaces for the metrics registry: a JSON artifact (written by
//! `--metrics-json`, validated like the bench artifacts), a
//! Prometheus-text exposition, and a human summary through the existing
//! `TableBuilder`.
//!
//! The JSON document is hand-emitted with the same helpers the bench
//! harness uses (`benchkit::json_str`/`json_num`) and is parseable by
//! the in-repo `config::json::Json` reader; [`validate_metrics_text`]
//! is the `dapc metrics-validate` / CI gate: a run that wrote an empty
//! registry, a non-finite value, a non-monotone quantile chain, or a
//! histogram whose buckets do not sum to its count fails loudly instead
//! of uploading a hollow artifact.

use std::collections::BTreeMap;

use crate::benchkit::{json_num, json_str};
use crate::config::json::Json;
use crate::error::{DapcError, Result};
use crate::metrics::TableBuilder;

use super::MetricsRegistry;

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: u64) -> String {
    let v = ns as f64;
    if v >= 1e9 {
        format!("{:.2}s", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}ms", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}us", v / 1e3)
    } else {
        format!("{ns}ns")
    }
}

impl MetricsRegistry {
    /// Serialize a snapshot as a JSON document (version 1).  Parseable
    /// by `config::json::Json`; checked by [`validate_metrics_text`].
    pub fn render_json(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        out.push_str("{\n  \"metrics_version\": 1,\n");
        out.push_str("  \"counters\": [");
        for (i, (name, v)) in snap.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": {}, \"value\": {v}}}",
                json_str(name)
            ));
        }
        out.push_str("\n  ],\n  \"gauges\": [");
        for (i, (name, v)) in snap.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": {}, \"value\": {}}}",
                json_str(name),
                json_num(*v)
            ));
        }
        out.push_str("\n  ],\n  \"histograms\": [");
        for (i, (name, h)) in snap.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": {}, \"count\": {}, \"sum_ns\": {}, \
                 \"min_ns\": {}, \"max_ns\": {}, \"p50_ns\": {}, \
                 \"p95_ns\": {}, \"p99_ns\": {}, \"p999_ns\": {}, \
                 \"buckets\": [",
                json_str(name),
                h.count,
                h.sum,
                h.min,
                h.max,
                h.p50,
                h.p95,
                h.p99,
                h.p999
            ));
            for (j, (b, c)) in h.buckets.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("[{b}, {c}]"));
            }
            out.push_str("]}");
        }
        out.push_str("\n  ]\n}\n");
        out
    }

    /// Prometheus text exposition: counters and gauges verbatim,
    /// histograms as summaries (`{quantile="..."}` series plus `_sum`
    /// and `_count`).  Names are sanitized to `dapc_[a-zA-Z0-9_]*`.
    pub fn render_prometheus(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        for (name, v) in &snap.counters {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (name, v) in &snap.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (name, h) in &snap.histograms {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} summary\n"));
            for (q, v) in [
                ("0.5", h.p50),
                ("0.95", h.p95),
                ("0.99", h.p99),
                ("0.999", h.p999),
            ] {
                out.push_str(&format!("{n}{{quantile=\"{q}\"}} {v}\n"));
            }
            out.push_str(&format!(
                "{n}_sum {}\n{n}_count {}\n",
                h.sum, h.count
            ));
        }
        out
    }

    /// Human summary: one table for counters/gauges, one for histogram
    /// quantiles.  Empty string when nothing is registered.
    pub fn render_table(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        if !snap.counters.is_empty() || !snap.gauges.is_empty() {
            let mut t = TableBuilder::new(&["metric", "value"]);
            for (name, v) in &snap.counters {
                t.row(&[name.clone(), v.to_string()]);
            }
            for (name, v) in &snap.gauges {
                t.row(&[name.clone(), format!("{v:.3}")]);
            }
            out.push_str(&t.render());
        }
        if !snap.histograms.is_empty() {
            if !out.is_empty() {
                out.push('\n');
            }
            let mut t = TableBuilder::new(&[
                "histogram", "count", "p50", "p95", "p99", "p99.9", "max",
            ]);
            for (name, h) in &snap.histograms {
                t.row(&[
                    name.clone(),
                    h.count.to_string(),
                    fmt_ns(h.p50),
                    fmt_ns(h.p95),
                    fmt_ns(h.p99),
                    fmt_ns(h.p999),
                    fmt_ns(h.max),
                ]);
            }
            out.push_str(&t.render());
        }
        out
    }
}

fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 5);
    out.push_str("dapc_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn req_num(rec: &Json, name: &str, key: &str) -> Result<f64> {
    rec.get(key).and_then(Json::as_f64).ok_or_else(|| {
        DapcError::Parse(format!(
            "metrics: {name:?} is missing numeric field {key:?}"
        ))
    })
}

fn check_nonneg(name: &str, key: &str, v: f64) -> Result<()> {
    if !v.is_finite() || v < 0.0 {
        return Err(DapcError::Parse(format!(
            "metrics: {name}.{key} = {v} is not a finite non-negative number"
        )));
    }
    Ok(())
}

/// Validate one rendered metrics document: it must parse with the
/// in-repo JSON reader, declare `metrics_version` 1, carry a non-empty
/// registry, and every value must be finite (counters and histogram
/// fields additionally non-negative).  Per histogram, the quantile
/// chain must be monotone (`p50 <= p95 <= p99 <= p999`) and the bucket
/// counts must sum exactly to `count`.  When the service-layer metrics
/// are present, the per-RHS histogram totals must equal the
/// `service.rhs_served` counter — every served RHS records exactly one
/// latency observation (warm or batched), so a drift here means an
/// instrumentation hole.  Likewise the per-session
/// `service.s{id}.resident_bytes` gauges must sum exactly to the
/// `service.resident_bytes` total when it is present — eviction and
/// unregister decrement both, so a drift means stale resident-memory
/// accounting.
///
/// Returns the total number of validated metrics.
pub fn validate_metrics_text(text: &str) -> Result<usize> {
    let doc = Json::parse(text)?;
    let ver = doc.get("metrics_version").and_then(Json::as_usize);
    if ver != Some(1) {
        return Err(DapcError::Parse(
            "metrics: missing or unsupported \"metrics_version\"".into(),
        ));
    }
    let arr = |key: &str| -> Result<&[Json]> {
        doc.get(key).and_then(Json::as_arr).ok_or_else(|| {
            DapcError::Parse(format!("metrics: missing {key:?} array"))
        })
    };
    let counters = arr("counters")?;
    let gauges = arr("gauges")?;
    let histograms = arr("histograms")?;
    if counters.is_empty() && gauges.is_empty() && histograms.is_empty() {
        return Err(DapcError::Parse(
            "metrics: registry is empty — nothing was recorded".into(),
        ));
    }

    let mut counter_vals: BTreeMap<String, f64> = BTreeMap::new();
    for c in counters {
        let name = c.req_str("name")?;
        let v = req_num(c, name, "value")?;
        check_nonneg(name, "value", v)?;
        counter_vals.insert(name.to_string(), v);
    }
    let mut gauge_vals: BTreeMap<String, f64> = BTreeMap::new();
    for g in gauges {
        let name = g.req_str("name")?;
        let v = req_num(g, name, "value")?;
        if !v.is_finite() {
            return Err(DapcError::Parse(format!(
                "metrics: gauge {name} = {v} is not finite"
            )));
        }
        gauge_vals.insert(name.to_string(), v);
    }

    let mut hist_counts: BTreeMap<String, f64> = BTreeMap::new();
    for h in histograms {
        let name = h.req_str("name")?;
        let count = req_num(h, name, "count")?;
        check_nonneg(name, "count", count)?;
        for key in [
            "sum_ns", "min_ns", "max_ns", "p50_ns", "p95_ns", "p99_ns",
            "p999_ns",
        ] {
            check_nonneg(name, key, req_num(h, name, key)?)?;
        }
        let p50 = req_num(h, name, "p50_ns")?;
        let p95 = req_num(h, name, "p95_ns")?;
        let p99 = req_num(h, name, "p99_ns")?;
        let p999 = req_num(h, name, "p999_ns")?;
        if count > 0.0 && !(p50 <= p95 && p95 <= p99 && p99 <= p999) {
            return Err(DapcError::Parse(format!(
                "metrics: {name} quantiles are not monotone \
                 ({p50} / {p95} / {p99} / {p999})"
            )));
        }
        let buckets = h.get("buckets").and_then(Json::as_arr).ok_or_else(
            || {
                DapcError::Parse(format!(
                    "metrics: {name} is missing \"buckets\""
                ))
            },
        )?;
        let mut total = 0.0;
        for pair in buckets {
            let pair = pair.as_arr().filter(|p| p.len() == 2).ok_or_else(
                || {
                    DapcError::Parse(format!(
                        "metrics: {name} bucket entries must be \
                         [index, count] pairs"
                    ))
                },
            )?;
            total += pair[1].as_f64().ok_or_else(|| {
                DapcError::Parse(format!(
                    "metrics: {name} bucket count is not a number"
                ))
            })?;
        }
        if total != count {
            return Err(DapcError::Parse(format!(
                "metrics: {name} buckets sum to {total} but count is \
                 {count} — dropped increments"
            )));
        }
        hist_counts.insert(name.to_string(), count);
    }

    if let Some(total) = gauge_vals.get("service.resident_bytes") {
        // per-session gauges must sum to the total: eviction and
        // unregister decrement both, so a drift here means the resident
        // accounting went stale (the bug this check exists to catch).
        // "service.resident_bytes" itself does not match the prefix.
        let mut per_session = 0.0;
        for (name, v) in &gauge_vals {
            if name.starts_with("service.s")
                && name.ends_with(".resident_bytes")
            {
                per_session += *v;
            }
        }
        if per_session != *total {
            return Err(DapcError::Parse(format!(
                "metrics: per-session resident-bytes gauges sum to \
                 {per_session} but service.resident_bytes says {total} \
                 — stale eviction/unregister accounting"
            )));
        }
    }

    if let Some(served) = counter_vals.get("service.rhs_served") {
        let warm =
            hist_counts.get("service.warm_rhs_ns").copied().unwrap_or(0.0);
        let batch =
            hist_counts.get("service.batch_rhs_ns").copied().unwrap_or(0.0);
        if warm + batch != *served {
            return Err(DapcError::Parse(format!(
                "metrics: per-RHS histogram totals ({warm} warm + {batch} \
                 batched) != service.rhs_served counter ({served})"
            )));
        }
    }

    Ok(counters.len() + gauges.len() + histograms.len())
}

/// [`validate_metrics_text`] over a file on disk, with the path in any
/// error.
pub fn validate_metrics_file(path: &std::path::Path) -> Result<usize> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        DapcError::Parse(format!("metrics: cannot read {}: {e}", path.display()))
    })?;
    validate_metrics_text(&text)
        .map_err(|e| DapcError::Parse(format!("{}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::super::{set_enabled, test_lock, MetricsRegistry};
    use super::*;

    fn populated() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        reg.counter("service.rhs_served").add(3);
        reg.gauge("cluster.workers").set(4.0);
        let warm = reg.histogram("service.warm_rhs_ns");
        warm.record(1_000);
        let batch = reg.histogram("service.batch_rhs_ns");
        batch.record(200);
        batch.record(300);
        reg
    }

    #[test]
    fn json_roundtrips_and_validates() {
        let _g = test_lock();
        set_enabled(true);
        let reg = populated();
        let text = reg.render_json();
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(
            doc.get("metrics_version").and_then(Json::as_usize),
            Some(1)
        );
        let n = validate_metrics_text(&text).expect("validates");
        assert_eq!(n, 4);
    }

    #[test]
    fn validator_rejects_empty_registry() {
        let reg = MetricsRegistry::new();
        let err = validate_metrics_text(&reg.render_json()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }

    #[test]
    fn validator_rejects_bucket_count_drift() {
        let doc = r#"{
          "metrics_version": 1,
          "counters": [], "gauges": [],
          "histograms": [
            {"name": "h", "count": 2, "sum_ns": 3, "min_ns": 1,
             "max_ns": 2, "p50_ns": 1, "p95_ns": 3, "p99_ns": 3,
             "p999_ns": 3, "buckets": [[1, 1]]}
          ]
        }"#;
        let err = validate_metrics_text(doc).unwrap_err();
        assert!(err.to_string().contains("dropped increments"), "{err}");
    }

    #[test]
    fn validator_rejects_non_monotone_quantiles() {
        let doc = r#"{
          "metrics_version": 1,
          "counters": [], "gauges": [],
          "histograms": [
            {"name": "h", "count": 1, "sum_ns": 3, "min_ns": 3,
             "max_ns": 3, "p50_ns": 7, "p95_ns": 3, "p99_ns": 7,
             "p999_ns": 7, "buckets": [[2, 1]]}
          ]
        }"#;
        let err = validate_metrics_text(doc).unwrap_err();
        assert!(err.to_string().contains("monotone"), "{err}");
    }

    #[test]
    fn validator_cross_checks_rhs_served() {
        let _g = test_lock();
        set_enabled(true);
        let reg = populated();
        // one more served RHS than histogram observations -> reject
        reg.counter("service.rhs_served").inc();
        let err = validate_metrics_text(&reg.render_json()).unwrap_err();
        assert!(err.to_string().contains("rhs_served"), "{err}");
    }

    #[test]
    fn validator_cross_checks_resident_bytes_gauges() {
        let _g = test_lock();
        set_enabled(true);
        let reg = MetricsRegistry::new();
        reg.gauge("service.resident_bytes").set(300.0);
        reg.gauge("service.s1.resident_bytes").set(100.0);
        reg.gauge("service.s2.resident_bytes").set(200.0);
        assert_eq!(validate_metrics_text(&reg.render_json()).unwrap(), 3);

        // stale accounting: an evicted session's gauge was never zeroed
        reg.gauge("service.s2.resident_bytes").set(0.0);
        let err = validate_metrics_text(&reg.render_json()).unwrap_err();
        assert!(err.to_string().contains("stale"), "{err}");
    }

    #[test]
    fn prometheus_exposition_shape() {
        let _g = test_lock();
        set_enabled(true);
        let reg = populated();
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE dapc_service_rhs_served counter"));
        assert!(text.contains("dapc_service_rhs_served 3"));
        assert!(text.contains("# TYPE dapc_cluster_workers gauge"));
        assert!(text.contains("# TYPE dapc_service_warm_rhs_ns summary"));
        assert!(text
            .contains("dapc_service_warm_rhs_ns{quantile=\"0.99\"}"));
        assert!(text.contains("dapc_service_warm_rhs_ns_count 1"));
    }

    #[test]
    fn table_renders_all_sections() {
        let _g = test_lock();
        set_enabled(true);
        let reg = populated();
        let text = reg.render_table();
        assert!(text.contains("service.rhs_served"));
        assert!(text.contains("service.batch_rhs_ns"));
        assert!(text.contains("p99.9"));
        assert!(MetricsRegistry::new().render_table().is_empty());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(5), "5ns");
        assert!(fmt_ns(5_000).ends_with("us"));
        assert!(fmt_ns(5_000_000).ends_with("ms"));
        assert!(fmt_ns(5_000_000_000).ends_with('s'));
    }
}
