//! [`ParallelEngine`]: the native engine's math fanned out over the
//! persistent thread pool.
//!
//! Parallelism is *structured for determinism*: every scalar operation
//! happens in the same order as on the sequential [`NativeEngine`], so
//! the two engines produce bit-identical iterates at any thread count.
//!
//! * eq. (6) updates are independent per partition — one pool job each;
//! * eq. (7) averaging splits the index range into contiguous chunks;
//!   within a chunk each output element still sums over partitions in
//!   fixed order j = 0..J;
//! * worker init / session registration (QR / Gram factorizations) is
//!   embarrassingly parallel across partitions
//!   ([`ComputeEngine::init_all`] / [`ComputeEngine::factorize_all`]);
//!   when partitions are scarcer than pool workers, partitions run
//!   sequentially and each panel-blocked QR instead fans its trailing
//!   updates over the whole pool
//!   ([`crate::linalg::qr::householder_qr_pooled`]) — both schedules are
//!   bit-identical, so the choice is purely about utilization;
//! * the DGD forward product `A x` is row-chunk parallel
//!   ([`crate::linalg::blas::gemv_pooled`]); the transposed reduction
//!   `A^T r` stays sequential because parallelizing it would reorder
//!   floating-point sums;
//! * the prepacked batched round fans (partition x MR-aligned row
//!   chunk) wide-gemm jobs over the pool: every output element is
//!   produced by exactly one microkernel tile whose accumulation order
//!   is a pure function of its coordinates, so any fan of disjoint row
//!   ranges is bit-identical to the serial sweep by construction.
//!
//! Jobs never nest scopes on the pool (that would deadlock a fully
//! occupied pool), which is why the per-partition round jobs call the
//! *serial* kernels.
//!
//! The scalar kernels these jobs run are themselves runtime-dispatched
//! ([`crate::linalg::simd`]): AVX2+FMA or the lane-structured scalar
//! fallback.  That dispatch is bit-deterministic by the same standard as
//! the scheduling above — `DAPC_FORCE_SCALAR=1`, like `--threads N`,
//! changes throughput and never a single output bit — so engine
//! equivalence holds across *both* axes at once.

use std::sync::Arc;

use crate::error::Result;
use crate::linalg::simd::{self, KernelTier, MR};
use crate::linalg::{blas, Matrix};
use crate::solver::engine::{
    average_chunk_kernel, check_average_shapes, check_dgd_shapes,
    check_prepacked_panels, check_round_batch_shapes, check_round_shapes,
    check_update_batch_packed_shapes, check_update_shapes, factorize_kernel,
    pack_batch_diffs, scale_batch_from_cbuf, update_batch_kernel,
    update_kernel, ComputeEngine, InitKind, NativeEngine, RoundWorkspace,
    SeedFactors, WorkerFactorization, WorkerInit,
};

use super::pool::ThreadPool;

/// Thread-pooled native engine (see module docs).
pub struct ParallelEngine {
    inner: NativeEngine,
    pool: Arc<ThreadPool>,
}

impl ParallelEngine {
    /// Engine over a fresh pool of `threads` workers (0 = one per
    /// available hardware thread), at the process-default kernel tier.
    pub fn new(threads: usize) -> Self {
        Self::with_pool(Arc::new(ThreadPool::new(threads)))
    }

    /// Engine over a shared pool (e.g. one pool for several solvers).
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        Self { inner: NativeEngine::new(), pool }
    }

    /// [`Self::new`] pinned to an explicit [`KernelTier`] — the pooled
    /// twin of [`NativeEngine::with_tier`].  The tier changes which f32
    /// gemm microkernel the factorizations run; it never touches the
    /// thread-count invariants (parallel == native stays bitwise at
    /// either tier, because the chunk-stable packing contract is
    /// tier-independent).
    pub fn with_tier(threads: usize, tier: KernelTier) -> Self {
        Self {
            inner: NativeEngine::with_tier(tier),
            pool: Arc::new(ThreadPool::new(threads)),
        }
    }

    /// The kernel tier this engine factorizes at.
    pub fn tier(&self) -> KernelTier {
        self.inner.tier()
    }

    /// Worker-thread count.
    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// The underlying pool, for sharing with other components.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Run `job(i)` for `i in 0..j` as one pool job each, collecting
    /// results in order — the shared fan-out scaffolding behind
    /// [`ComputeEngine::init_all`] and [`ComputeEngine::factorize_all`].
    /// Jobs must not touch the pool themselves (nesting scopes on a
    /// saturated pool would deadlock), which is why both callers hand
    /// their job the *serial* inner engine.
    fn fan_out<T: Send>(
        &self,
        j: usize,
        job: impl Fn(usize) -> Result<T> + Sync,
    ) -> Result<Vec<T>> {
        let mut slots: Vec<Option<Result<T>>> = Vec::new();
        slots.resize_with(j, || None);
        let job = &job;
        self.pool.scope(|s| {
            for (i, slot) in slots.iter_mut().enumerate() {
                s.spawn(move || {
                    *slot = Some(job(i));
                });
            }
        });
        slots
            .into_iter()
            .map(|r| r.expect("pool job completed"))
            .collect()
    }

    /// The hybrid init/registration schedule, in ONE place: with
    /// partitions scarcer than workers AND a factorization that can use
    /// the pool itself (the panel-blocked QR paths; Classical's Gram
    /// route is serial inside), sequential partitions each fanning their
    /// trailing updates over the whole pool beat partition-parallel jobs
    /// that would idle `size - j` workers.  Every schedule is
    /// bit-identical — this is purely a utilization choice.
    fn whole_pool_per_factorization(&self, j: usize, kind: InitKind) -> bool {
        j < self.pool.size() && kind != InitKind::Classical
    }

    /// Chunked-parallel eq. (7); shapes must be pre-validated.  Generic
    /// over the estimate container so the batched round can pass
    /// per-column `&[f32]` views.
    fn average_chunks<S: AsRef<[f32]> + Sync>(
        &self,
        xs: &[S],
        xbar: &[f32],
        eta: f32,
        acc: &mut [f64],
        out: &mut [f32],
    ) {
        let n = out.len();
        if n == 0 {
            return;
        }
        let acc = &mut acc[..n];
        let parts = self.pool.size().min(n).max(1);
        let chunk = n.div_ceil(parts);
        self.pool.scope(|s| {
            for (ci, (acc_c, out_c)) in
                acc.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
            {
                let lo = ci * chunk;
                s.spawn(move || {
                    average_chunk_kernel(xs, xbar, eta, lo, acc_c, out_c)
                });
            }
        });
    }
}

impl std::fmt::Debug for ParallelEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParallelEngine")
            .field("threads", &self.pool.size())
            .finish()
    }
}

impl ComputeEngine for ParallelEngine {
    fn init(
        &self,
        kind: InitKind,
        a: &Matrix,
        b: &[f32],
        n_target: usize,
    ) -> Result<WorkerInit> {
        // pooled factorize + seed IS the cold init, mirroring
        // NativeEngine::init: warm re-seeds stay bit-identical to cold
        // solves by construction, and a lone leader-side init gets the
        // panel-blocked QR's trailing-update parallelism
        let fac = self.factorize(kind, a, n_target)?;
        let x0 = self.inner.seed(&fac.seed, a, b)?;
        Ok(WorkerInit { x0, projector: fac.projector })
    }

    fn init_all(
        &self,
        kind: InitKind,
        j: usize,
        extract: &(dyn Fn(usize) -> (Matrix, Vec<f32>) + Sync),
        n_target: usize,
    ) -> Result<Vec<WorkerInit>> {
        if self.whole_pool_per_factorization(j, kind) {
            return (0..j)
                .map(|i| {
                    let (a, b) = extract(i);
                    self.init(kind, &a, &b, n_target)
                })
                .collect();
        }
        let inner = &self.inner;
        self.fan_out(j, |i| {
            // densify inside the job too: at most `threads` dense
            // blocks are ever live at once
            let (a, b) = extract(i);
            inner.init(kind, &a, &b, n_target)
        })
    }

    fn update(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let n = x.len();
        let mut scratch = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        self.update_into(x, xbar, p, gamma, &mut scratch, &mut out)?;
        Ok(out)
    }

    fn update_into(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
        scratch: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        check_update_shapes(x, xbar, p, scratch.len(), out.len())?;
        // the single-update entry point is leader-side, outside any
        // scope, so the pooled matvec cannot nest
        for ((d, &xb), &xi) in scratch.iter_mut().zip(xbar).zip(x) {
            *d = xb - xi;
        }
        blas::gemv_pooled(&self.pool, p, scratch, out);
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = xi + gamma * *o;
        }
        Ok(())
    }

    fn average(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        eta: f32,
    ) -> Result<Vec<f32>> {
        let n = xbar.len();
        let mut acc = vec![0.0f64; n];
        let mut out = vec![0.0f32; n];
        self.average_into(xs, xbar, eta, &mut acc, &mut out)?;
        Ok(out)
    }

    fn average_into(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        eta: f32,
        acc: &mut [f64],
        out: &mut [f32],
    ) -> Result<()> {
        check_average_shapes(xs, xbar.len(), acc.len(), out.len())?;
        self.average_chunks(xs, xbar, eta, acc, out);
        Ok(())
    }

    fn round(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let mut out_xs: Vec<Vec<f32>> =
            xs.iter().map(|x| vec![0.0f32; x.len()]).collect();
        let mut out_xbar = vec![0.0f32; xbar.len()];
        let mut ws = RoundWorkspace::for_shape(xs.len(), xbar.len());
        self.round_into(
            xs,
            xbar,
            ps,
            gamma,
            eta,
            &mut ws,
            &mut out_xs,
            &mut out_xbar,
        )?;
        Ok((out_xs, out_xbar))
    }

    fn round_into(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
        ws: &mut RoundWorkspace,
        out_xs: &mut [Vec<f32>],
        out_xbar: &mut [f32],
    ) -> Result<()> {
        let j = xs.len();
        let n = xbar.len();
        check_round_shapes(xs, ps, out_xs, n)?;
        ws.ensure(j, n);
        // eq. (6): one pool job per partition, each writing its own
        // scratch + output buffers (disjoint by construction)
        let scratches = &mut ws.scratch[..j];
        self.pool.scope(|s| {
            for (((x, p), scratch), out) in xs
                .iter()
                .zip(ps)
                .zip(scratches.iter_mut())
                .zip(out_xs.iter_mut())
            {
                s.spawn(move || {
                    update_kernel(x, xbar, p, gamma, scratch, out)
                });
            }
        });
        // eq. (7): chunked over the index range
        self.average_chunks(&*out_xs, xbar, eta, &mut ws.acc, out_xbar);
        Ok(())
    }

    fn factorize(
        &self,
        kind: InitKind,
        a: &Matrix,
        n_target: usize,
    ) -> Result<WorkerFactorization> {
        // the shared kernel with pooled trailing updates — bit-identical
        // to the native engine's serial run, so sessions re-seed
        // identically no matter which engine (at which thread count)
        // registered the matrix
        factorize_kernel(kind, a, n_target, Some(&self.pool), self.inner.tier())
    }

    fn factorize_all(
        &self,
        kind: InitKind,
        blocks: &[Matrix],
        n_target: usize,
    ) -> Result<Vec<WorkerFactorization>> {
        if self.whole_pool_per_factorization(blocks.len(), kind) {
            return blocks
                .iter()
                .map(|a| self.factorize(kind, a, n_target))
                .collect();
        }
        let inner = &self.inner;
        self.fan_out(blocks.len(), |i| {
            inner.factorize(kind, &blocks[i], n_target)
        })
    }

    fn seed(
        &self,
        seed: &SeedFactors,
        a: &Matrix,
        b: &[f32],
    ) -> Result<Vec<f32>> {
        self.inner.seed(seed, a, b)
    }

    fn round_batch_into(
        &self,
        xs: &[Vec<Vec<f32>>],
        xbars: &[Vec<f32>],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
        ws: &mut RoundWorkspace,
        out_xs: &mut [Vec<Vec<f32>>],
        out_xbars: &mut [Vec<f32>],
    ) -> Result<()> {
        let (j, k, n) =
            check_round_batch_shapes(xs, xbars, ps, out_xs, out_xbars)?;
        ws.ensure_batch(j, k, n);
        // eq. (6): one pool job per partition; each job sweeps its
        // projector once for all k columns through the batched kernel
        // (buffers disjoint by construction, so determinism holds)
        let scratches = &mut ws.scratch[..j * k];
        self.pool.scope(|s| {
            for (((x, p), scratch), out) in xs
                .iter()
                .zip(ps)
                .zip(scratches.chunks_mut(k))
                .zip(out_xs.iter_mut())
            {
                s.spawn(move || {
                    update_batch_kernel(x, xbars, p, gamma, scratch, out)
                });
            }
        });
        // eq. (7): per column, chunked exactly like the single-RHS path
        let mut cols: Vec<&[f32]> = Vec::with_capacity(j);
        for (c, (xbar, out_xbar)) in
            xbars.iter().zip(out_xbars.iter_mut()).enumerate()
        {
            cols.clear();
            cols.extend(out_xs.iter().map(|xj| xj[c].as_slice()));
            self.average_chunks(&cols, xbar, eta, &mut ws.acc, out_xbar);
        }
        Ok(())
    }

    fn round_batch_packed_into(
        &self,
        xs: &[Vec<Vec<f32>>],
        xbars: &[Vec<f32>],
        ps: &[Matrix],
        panels: &[blas::PrepackedPanels],
        gamma: f32,
        eta: f32,
        ws: &mut RoundWorkspace,
        out_xs: &mut [Vec<Vec<f32>>],
        out_xbars: &mut [Vec<f32>],
    ) -> Result<()> {
        let (j, k, n) =
            check_round_batch_shapes(xs, xbars, ps, out_xs, out_xbars)?;
        check_prepacked_panels(panels, j, n)?;
        if n == 0 {
            return Ok(());
        }
        ws.ensure_packed(j, k, n);
        // stage 1: pack each partition's k diff columns into B-panel
        // layout — one pool job per partition, disjoint buffers
        self.pool.scope(|s| {
            for (x, bp) in xs.iter().zip(ws.bpack[..j].iter_mut()) {
                s.spawn(move || pack_batch_diffs(x, xbars, n, bp));
            }
        });
        // stage 2: the packed projector sweeps, fanned over
        // (partition x MR-aligned row chunk).  Each output element comes
        // from exactly one wide-microkernel tile, so this fan reproduces
        // the serial sweep bit for bit at any thread count.
        let backend = simd::active();
        let chunks = self.pool.size().div_ceil(j).max(1);
        let rows_per = n.div_ceil(chunks).div_ceil(MR) * MR;
        let bpacks = &ws.bpack[..j];
        let cbufs = &mut ws.cbuf[..j];
        self.pool.scope(|s| {
            for ((panel, bp), cbuf) in
                panels.iter().zip(bpacks).zip(cbufs.iter_mut())
            {
                for (ci, cchunk) in
                    cbuf[..n * k].chunks_mut(rows_per * k).enumerate()
                {
                    let lo = ci * rows_per;
                    let rows = cchunk.len() / k;
                    s.spawn(move || {
                        blas::packed_gemm_prepacked_into(
                            backend,
                            KernelTier::Deterministic,
                            panel,
                            lo,
                            rows,
                            k,
                            bp,
                            cchunk,
                            k,
                            1,
                        );
                    });
                }
            }
        });
        // stage 3: scatter + eq. (6) relaxation, one job per partition
        self.pool.scope(|s| {
            for ((x, cbuf), out) in
                xs.iter().zip(ws.cbuf[..j].iter()).zip(out_xs.iter_mut())
            {
                s.spawn(move || scale_batch_from_cbuf(x, cbuf, gamma, k, out));
            }
        });
        // eq. (7): per column, chunked exactly like the row-dot path
        let mut cols: Vec<&[f32]> = Vec::with_capacity(j);
        for (c, (xbar, out_xbar)) in
            xbars.iter().zip(out_xbars.iter_mut()).enumerate()
        {
            cols.clear();
            cols.extend(out_xs.iter().map(|xj| xj[c].as_slice()));
            self.average_chunks(&cols, xbar, eta, &mut ws.acc, out_xbar);
        }
        Ok(())
    }

    fn update_batch_packed(
        &self,
        xs: &[Vec<f32>],
        xbars: &[Vec<f32>],
        panels: &blas::PrepackedPanels,
        gamma: f32,
    ) -> Result<Vec<Vec<f32>>> {
        let (k, n) = check_update_batch_packed_shapes(xs, xbars, panels)?;
        if k == 0 {
            return Ok(Vec::new());
        }
        if n == 0 {
            return Ok(vec![Vec::new(); k]);
        }
        let mut bpack = vec![0.0f32; blas::packed_b_len(n, k)];
        pack_batch_diffs(xs, xbars, n, &mut bpack);
        let mut cbuf = vec![0.0f32; n * k];
        // MR-aligned row chunks over the pool — same tile-per-element
        // argument as the batched round, so this matches the serial
        // default bitwise
        let backend = simd::active();
        let rows_per = n.div_ceil(self.pool.size().max(1)).div_ceil(MR) * MR;
        let bp = &bpack;
        self.pool.scope(|s| {
            for (ci, cchunk) in cbuf.chunks_mut(rows_per * k).enumerate() {
                let lo = ci * rows_per;
                let rows = cchunk.len() / k;
                s.spawn(move || {
                    blas::packed_gemm_prepacked_into(
                        backend,
                        KernelTier::Deterministic,
                        panels,
                        lo,
                        rows,
                        k,
                        bp,
                        cchunk,
                        k,
                        1,
                    );
                });
            }
        });
        let mut out = vec![vec![0.0f32; n]; k];
        scale_batch_from_cbuf(xs, &cbuf, gamma, k, &mut out);
        Ok(out)
    }

    fn dgd_grad(&self, a: &Matrix, x: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let mut ax = vec![0.0f32; a.rows()];
        let mut g = vec![0.0f32; a.cols()];
        self.dgd_grad_into(a, x, b, &mut ax, &mut g)?;
        Ok(g)
    }

    fn dgd_grad_into(
        &self,
        a: &Matrix,
        x: &[f32],
        b: &[f32],
        ax_scratch: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        check_dgd_shapes(a, x, b, ax_scratch.len(), out.len())?;
        blas::gemv_pooled(&self.pool, a, x, ax_scratch);
        for (axi, bi) in ax_scratch.iter_mut().zip(b) {
            *axi -= bi;
        }
        blas::gemv_t(a, ax_scratch, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "parallel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut g = seeded(seed);
        (0..n).map(|_| g.normal_f32()).collect()
    }

    #[test]
    fn round_bitwise_matches_native() {
        let native = NativeEngine::new();
        for &(j, n) in &[(1usize, 8usize), (3, 19), (4, 64), (5, 37)] {
            let par = ParallelEngine::new(3);
            let xs: Vec<Vec<f32>> =
                (0..j).map(|i| randv(n, 100 + i as u64)).collect();
            let xbar = randv(n, 200);
            let ps: Vec<Matrix> =
                (0..j).map(|i| randm(n, n, 300 + i as u64)).collect();
            let (nx, nb) = native.round(&xs, &xbar, &ps, 0.7, 0.6).unwrap();
            let (px, pb) = par.round(&xs, &xbar, &ps, 0.7, 0.6).unwrap();
            assert_eq!(nx, px, "(j={j}, n={n})");
            assert_eq!(nb, pb, "(j={j}, n={n})");
        }
    }

    #[test]
    fn average_and_update_bitwise_match_native() {
        let native = NativeEngine::new();
        let par = ParallelEngine::new(4);
        let (j, n) = (3, 41); // n indivisible by any chunking
        let xs: Vec<Vec<f32>> =
            (0..j).map(|i| randv(n, 10 + i as u64)).collect();
        let xbar = randv(n, 20);
        let p = randm(n, n, 21);
        assert_eq!(
            native.average(&xs, &xbar, 0.85).unwrap(),
            par.average(&xs, &xbar, 0.85).unwrap()
        );
        assert_eq!(
            native.update(&xs[0], &xbar, &p, 0.9).unwrap(),
            par.update(&xs[0], &xbar, &p, 0.9).unwrap()
        );
    }

    #[test]
    fn dgd_grad_bitwise_matches_native() {
        let native = NativeEngine::new();
        let par = ParallelEngine::new(2);
        let a = randm(23, 9, 31);
        let x = randv(9, 32);
        let b = randv(23, 33);
        assert_eq!(
            native.dgd_grad(&a, &x, &b).unwrap(),
            par.dgd_grad(&a, &x, &b).unwrap()
        );
    }

    #[test]
    fn init_all_parallel_matches_serial() {
        let par = ParallelEngine::new(3);
        let blocks: Vec<(Matrix, Vec<f32>)> = (0..4)
            .map(|i| (randm(20, 6, 50 + i as u64), randv(20, 60 + i as u64)))
            .collect();
        let extract = |i: usize| blocks[i].clone();
        let native = NativeEngine::new();
        let serial = native.init_all(InitKind::Qr, 4, &extract, 6).unwrap();
        let parallel = par.init_all(InitKind::Qr, 4, &extract, 6).unwrap();
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.x0, p.x0);
            assert_eq!(
                s.projector.as_slice(),
                p.projector.as_slice()
            );
        }
    }

    #[test]
    fn round_batch_bitwise_matches_native() {
        let native = NativeEngine::new();
        let par = ParallelEngine::new(3);
        let (j, k, n) = (3usize, 4usize, 29usize); // odd n: ragged chunks
        let xs: Vec<Vec<Vec<f32>>> = (0..j)
            .map(|i| {
                (0..k)
                    .map(|c| randv(n, 1000 + (i * k + c) as u64))
                    .collect()
            })
            .collect();
        let xbars: Vec<Vec<f32>> =
            (0..k).map(|c| randv(n, 2000 + c as u64)).collect();
        let ps: Vec<Matrix> =
            (0..j).map(|i| randm(n, n, 3000 + i as u64)).collect();

        let mut nws = RoundWorkspace::default();
        let mut n_xs: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; n]; k]; j];
        let mut n_xbars: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
        native
            .round_batch_into(
                &xs, &xbars, &ps, 0.7, 0.6, &mut nws, &mut n_xs,
                &mut n_xbars,
            )
            .unwrap();

        let mut pws = RoundWorkspace::default();
        let mut p_xs: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; n]; k]; j];
        let mut p_xbars: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
        par.round_batch_into(
            &xs, &xbars, &ps, 0.7, 0.6, &mut pws, &mut p_xs, &mut p_xbars,
        )
        .unwrap();

        assert_eq!(n_xs, p_xs);
        assert_eq!(n_xbars, p_xbars);
    }

    #[test]
    fn round_batch_packed_bitwise_matches_native_at_any_thread_count() {
        let native = NativeEngine::new();
        let (j, k, n) = (3usize, 4usize, 29usize); // odd n: ragged chunks
        let xs: Vec<Vec<Vec<f32>>> = (0..j)
            .map(|i| {
                (0..k)
                    .map(|c| randv(n, 1100 + (i * k + c) as u64))
                    .collect()
            })
            .collect();
        let xbars: Vec<Vec<f32>> =
            (0..k).map(|c| randv(n, 2100 + c as u64)).collect();
        let ps: Vec<Matrix> =
            (0..j).map(|i| randm(n, n, 3100 + i as u64)).collect();
        let panels: Vec<blas::PrepackedPanels> =
            ps.iter().map(blas::PrepackedPanels::from_matrix).collect();

        let mut nws = RoundWorkspace::default();
        let mut n_xs: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; n]; k]; j];
        let mut n_xbars: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
        native
            .round_batch_packed_into(
                &xs, &xbars, &ps, &panels, 0.7, 0.6, &mut nws, &mut n_xs,
                &mut n_xbars,
            )
            .unwrap();

        for threads in [1usize, 2, 7] {
            let par = ParallelEngine::new(threads);
            let mut pws = RoundWorkspace::default();
            let mut p_xs: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; n]; k]; j];
            let mut p_xbars: Vec<Vec<f32>> = vec![vec![0.0; n]; k];
            par.round_batch_packed_into(
                &xs, &xbars, &ps, &panels, 0.7, 0.6, &mut pws, &mut p_xs,
                &mut p_xbars,
            )
            .unwrap();
            assert_eq!(n_xs, p_xs, "threads={threads}");
            assert_eq!(n_xbars, p_xbars, "threads={threads}");
        }
    }

    #[test]
    fn update_batch_packed_bitwise_matches_native() {
        let native = NativeEngine::new();
        let par = ParallelEngine::new(3);
        let (n, k) = (23usize, 5usize);
        let p = randm(n, n, 4100);
        let panels = blas::PrepackedPanels::from_matrix(&p);
        let xs: Vec<Vec<f32>> =
            (0..k).map(|c| randv(n, 5100 + c as u64)).collect();
        let xbars: Vec<Vec<f32>> =
            (0..k).map(|c| randv(n, 6100 + c as u64)).collect();
        assert_eq!(
            native.update_batch_packed(&xs, &xbars, &panels, 0.8).unwrap(),
            par.update_batch_packed(&xs, &xbars, &panels, 0.8).unwrap()
        );
    }

    #[test]
    fn factorize_and_seed_bitwise_match_native() {
        // the pooled panel-blocked QR must reproduce the serial kernel
        // exactly — the warm-session bit-identity contract across engines
        let native = NativeEngine::new();
        let par = ParallelEngine::new(2);
        let a = randm(24, 8, 41);
        let b = randv(24, 42);
        let nf = native.factorize(InitKind::Qr, &a, 8).unwrap();
        let pf = par.factorize(InitKind::Qr, &a, 8).unwrap();
        assert_eq!(nf.projector.as_slice(), pf.projector.as_slice());
        assert_eq!(
            native.seed(&nf.seed, &a, &b).unwrap(),
            par.seed(&pf.seed, &a, &b).unwrap()
        );
    }

    #[test]
    fn factorize_all_bitwise_matches_native_at_any_partition_count() {
        // j below the pool size takes the sequential-with-pooled-QR
        // schedule, j above it the partition-parallel one; both must be
        // bit-identical to the native engine
        let native = NativeEngine::new();
        let par = ParallelEngine::new(3);
        for j in [1usize, 2, 5] {
            let blocks: Vec<Matrix> =
                (0..j).map(|i| randm(26, 7, 900 + i as u64)).collect();
            let nf = native.factorize_all(InitKind::Qr, &blocks, 7).unwrap();
            let pf = par.factorize_all(InitKind::Qr, &blocks, 7).unwrap();
            assert_eq!(nf.len(), j);
            for (i, (n, p)) in nf.iter().zip(&pf).enumerate() {
                assert_eq!(
                    n.projector.as_slice(),
                    p.projector.as_slice(),
                    "j={j} partition {i}"
                );
            }
        }
    }

    #[test]
    fn factorize_all_error_propagates() {
        let par = ParallelEngine::new(2);
        // n_target mismatch is a reported error on both schedules
        let blocks: Vec<Matrix> =
            (0..4).map(|i| randm(10, 4, 80 + i as u64)).collect();
        assert!(par.factorize_all(InitKind::Qr, &blocks[..1], 5).is_err());
        assert!(par.factorize_all(InitKind::Qr, &blocks, 5).is_err());
    }

    #[test]
    fn init_error_propagates_from_pool_jobs() {
        let par = ParallelEngine::new(2);
        let block = (randm(8, 4, 70), randv(8, 71));
        // n_target mismatch is a reported error, not a panic
        assert!(par
            .init_all(InitKind::Qr, 1, &|_| block.clone(), 5)
            .is_err());
    }
}
