//! Contiguous row partitioner — mirrors the paper's `create_submatrices`
//! (chunk_size = len(b) // J, last chunk absorbs the remainder).

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;
use crate::sparse::CsrMatrix;

/// Which APC regime a partition plan targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionRegime {
    /// `l >= n` rows per block (this paper's setting: each block is an
    /// overdetermined/square solvable system; projector is rounding-noise).
    Tall,
    /// `l < n` rows per block (the original APC [7] setting: genuine
    /// nullspace projectors, consensus does real work).
    Fat,
}

/// One partition's row range.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowBlock {
    pub index: usize,
    pub start: usize,
    pub end: usize,
}

impl RowBlock {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// A full partitioning of an (m x n) system into J contiguous row blocks.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub blocks: Vec<RowBlock>,
    pub n: usize,
    pub regime: PartitionRegime,
}

impl PartitionPlan {
    /// Split `m` rows into `j` contiguous blocks, paper-style: the first
    /// J-1 blocks get `m / j` rows, the last absorbs the remainder (the
    /// paper's `create_submatrices` merges the tail into the final chunk).
    pub fn contiguous(m: usize, n: usize, j: usize) -> Result<Self> {
        if j == 0 {
            return Err(DapcError::Config("J must be >= 1".into()));
        }
        if m < j {
            return Err(DapcError::Config(format!(
                "cannot split {m} rows into {j} partitions"
            )));
        }
        let chunk = m / j;
        let mut blocks = Vec::with_capacity(j);
        for i in 0..j {
            let start = i * chunk;
            let end = if i == j - 1 { m } else { start + chunk };
            blocks.push(RowBlock { index: i, start, end });
        }
        let min_len = blocks.iter().map(RowBlock::len).min().unwrap();
        let regime = if min_len >= n {
            PartitionRegime::Tall
        } else {
            PartitionRegime::Fat
        };
        Ok(Self { blocks, n, regime })
    }

    /// Like [`Self::contiguous`] but *requires* the tall regime the paper
    /// assumes (`(m+n)/J >= n`, §4): errors out otherwise.
    pub fn contiguous_tall(m: usize, n: usize, j: usize) -> Result<Self> {
        let plan = Self::contiguous(m, n, j)?;
        if plan.regime != PartitionRegime::Tall {
            return Err(DapcError::Config(format!(
                "partition too fine: {m} rows / {j} blocks gives blocks \
                 smaller than n = {n} (paper §4 requires (m+n)/J >= n)"
            )));
        }
        Ok(plan)
    }

    pub fn j(&self) -> usize {
        self.blocks.len()
    }

    /// Densify block `i` of a CSR matrix + rhs (paper's worker step 1).
    pub fn extract(
        &self,
        a: &CsrMatrix,
        b: &[f32],
        i: usize,
    ) -> (Matrix, Vec<f32>) {
        let blk = self.blocks[i];
        let sub = a.slice_rows_dense(blk.start, blk.end);
        let rhs = b[blk.start..blk.end].to_vec();
        (sub, rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::generate::GeneratorConfig;

    #[test]
    fn even_split() {
        let p = PartitionPlan::contiguous(100, 10, 4).unwrap();
        assert_eq!(p.j(), 4);
        assert!(p.blocks.iter().all(|b| b.len() == 25));
        assert_eq!(p.regime, PartitionRegime::Tall);
    }

    #[test]
    fn remainder_goes_to_last_block() {
        let p = PartitionPlan::contiguous(103, 10, 4).unwrap();
        assert_eq!(p.blocks[0].len(), 25);
        assert_eq!(p.blocks[3].len(), 28);
        // blocks tile [0, m) exactly
        let mut cursor = 0;
        for b in &p.blocks {
            assert_eq!(b.start, cursor);
            cursor = b.end;
        }
        assert_eq!(cursor, 103);
    }

    #[test]
    fn fat_regime_detected() {
        let p = PartitionPlan::contiguous(64, 32, 4).unwrap();
        assert_eq!(p.regime, PartitionRegime::Fat); // 16 rows < n=32
        assert!(PartitionPlan::contiguous_tall(64, 32, 4).is_err());
        assert!(PartitionPlan::contiguous_tall(64, 32, 2).is_ok());
    }

    #[test]
    fn degenerate_cases() {
        assert!(PartitionPlan::contiguous(10, 5, 0).is_err());
        assert!(PartitionPlan::contiguous(3, 5, 4).is_err());
        let p = PartitionPlan::contiguous(10, 5, 1).unwrap();
        assert_eq!(p.blocks[0].len(), 10);
    }

    #[test]
    fn extract_matches_source() {
        let ds = GeneratorConfig::small_demo(8, 2).generate(5);
        let p = PartitionPlan::contiguous_tall(ds.matrix.rows(), 8, 3).unwrap();
        let (sub, rhs) = p.extract(&ds.matrix, &ds.rhs, 1);
        let blk = p.blocks[1];
        assert_eq!(sub.shape(), (blk.len(), 8));
        assert_eq!(rhs.len(), blk.len());
        for r in 0..blk.len() {
            for c in 0..8 {
                assert_eq!(sub[(r, c)], ds.matrix.get(blk.start + r, c));
            }
            assert_eq!(rhs[r], ds.rhs[blk.start + r]);
        }
    }
}
