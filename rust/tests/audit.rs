//! Self-test for the `dapc audit` static-analysis pass.
//!
//! Two halves:
//!
//! 1. **Seeded violations** — every fixture under `tests/audit_fixtures/`
//!    is scanned under a pretend repo path and must trip exactly the rule
//!    its name says (and clean twins must not).  This is the proof that
//!    the analyzer detects what it claims to detect: a rule that silently
//!    stops firing fails here, not in a post-mortem.
//! 2. **The repo itself audits clean** — `audit_root` over this checkout
//!    reports zero unsuppressed findings, which is exactly what the
//!    `cargo run -- audit --ci` CI step enforces on every leg.

use std::fs;
use std::path::Path;

use dapc::audit::{self, Rule};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/audit_fixtures")
        .join(name);
    fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Scan a fixture under a pretend root-relative path; return the rules
/// that fired (in report order) and the suppression count.
fn scan(name: &str, pretend: &str) -> (Vec<Rule>, usize) {
    let (findings, suppressed) = audit::scan_source(pretend, &fixture(name));
    (findings.iter().map(|f| f.rule).collect(), suppressed)
}

#[test]
fn undocumented_unsafe_fires_both_ways() {
    // outside the kernel/pool allowlist: confinement violation
    let (rules, _) = scan("unsafe_undocumented.rs", "rust/src/solver/mod.rs");
    assert_eq!(rules, vec![Rule::UnsafeConfined]);
    let (findings, _) = audit::scan_source(
        "rust/src/solver/mod.rs",
        &fixture("unsafe_undocumented.rs"),
    );
    assert!(findings[0].message.contains("outside"), "{}", findings[0].render());

    // inside the allowlist: still a violation, but for the missing
    // SAFETY comment (the blank line breaks the comment chain)
    let (rules, _) = scan("unsafe_undocumented.rs", "rust/src/linalg/simd.rs");
    assert_eq!(rules, vec![Rule::UnsafeConfined]);
    let (findings, _) = audit::scan_source(
        "rust/src/linalg/simd.rs",
        &fixture("unsafe_undocumented.rs"),
    );
    assert!(findings[0].message.contains("SAFETY"), "{}", findings[0].render());
}

#[test]
fn documented_unsafe_is_clean_inside_the_allowlist() {
    let (rules, _) = scan("unsafe_documented.rs", "rust/src/linalg/simd.rs");
    assert!(rules.is_empty(), "clean twin fired: {rules:?}");
    let (rules, _) = scan("unsafe_documented.rs", "rust/src/parallel/pool.rs");
    assert!(rules.is_empty(), "clean twin fired in pool.rs: {rules:?}");
    // documentation does not excuse a site outside the allowlist
    let (rules, _) = scan("unsafe_documented.rs", "rust/src/sparse/csr.rs");
    assert_eq!(rules, vec![Rule::UnsafeConfined]);
}

#[test]
fn hashmap_fires_outside_runtime_only() {
    let (rules, _) = scan("hashmap_use.rs", "rust/src/rng/xoshiro.rs");
    assert!(!rules.is_empty());
    assert!(rules.iter().all(|&r| r == Rule::NoHashmap), "{rules:?}");
    // the xla-gated runtime/ is exempt (host-side caches, order never
    // observable in numerics)
    let (rules, _) = scan("hashmap_use.rs", "rust/src/runtime/cache.rs");
    assert!(rules.is_empty(), "runtime/ should be exempt: {rules:?}");
}

#[test]
fn fused_float_fires_outside_simd_only() {
    let (rules, _) = scan("fused_float.rs", "rust/src/linalg/blas.rs");
    assert_eq!(rules, vec![Rule::NoFusedFloat]);
    let (rules, _) = scan("fused_float.rs", "rust/src/linalg/simd.rs");
    assert!(rules.is_empty(), "simd.rs should be exempt: {rules:?}");
}

#[test]
fn float_reduce_fires_outside_linalg_only() {
    let (rules, _) = scan("float_reduce.rs", "rust/src/solver/native.rs");
    // the typed sum and the float-seeded fold fire; the integer fold
    // must not
    assert_eq!(rules, vec![Rule::FixedOrderReduce, Rule::FixedOrderReduce]);
    let (rules, _) = scan("float_reduce.rs", "rust/src/linalg/norms.rs");
    assert!(rules.is_empty(), "linalg/ should be exempt: {rules:?}");
}

#[test]
fn raw_dapc_env_read_fires_anywhere_but_the_registry() {
    let (rules, _) = scan("env_read.rs", "rust/src/obs/mod.rs");
    // exactly one: the DAPC_* read — the HOME read is out of scope
    assert_eq!(rules, vec![Rule::EnvRegistry]);
    let (rules, _) = scan("env_read.rs", "rust/tests/some_test.rs");
    assert_eq!(rules, vec![Rule::EnvRegistry], "tests are audited too");
    let (rules, _) = scan("env_read.rs", "rust/src/config/envvars.rs");
    assert!(rules.is_empty(), "the registry itself is exempt: {rules:?}");
}

#[test]
fn unpaired_wire_variant_fires() {
    let (findings, _) = audit::scan_source(
        "rust/src/coordinator/message.rs",
        &fixture("wire_unpaired.rs"),
    );
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, Rule::WirePairing);
    assert!(
        findings[0].message.contains("`Pong` never appears in a decode arm"),
        "{}",
        findings[0].render()
    );
    // the pairing rule only runs under the real wire module's path
    let (rules, _) = scan("wire_unpaired.rs", "rust/src/coordinator/leader.rs");
    assert!(rules.is_empty(), "{rules:?}");
}

#[test]
fn justified_allow_suppresses_and_is_counted() {
    let (rules, suppressed) =
        scan("allow_justified.rs", "rust/src/metrics/trace.rs");
    assert!(rules.is_empty(), "justified allow did not suppress: {rules:?}");
    assert_eq!(suppressed, 1);
}

#[test]
fn reasonless_allow_does_not_suppress() {
    let (findings, suppressed) = audit::scan_source(
        "rust/src/metrics/trace.rs",
        &fixture("allow_no_reason.rs"),
    );
    assert_eq!(suppressed, 0);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, Rule::FixedOrderReduce);
    assert!(
        findings[0].message.contains("does not suppress"),
        "the finding should explain why the marker was ignored: {}",
        findings[0].render()
    );
}

#[test]
fn the_repo_itself_audits_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .to_path_buf();
    let report = audit::audit_root(&root).expect("audit walk");
    assert!(report.files_scanned > 40, "only {} files", report.files_scanned);
    assert!(
        report.clean(),
        "repo has {} unsuppressed finding(s):\n{}",
        report.findings.len(),
        report
            .findings
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
    // the suppressions placed in-tree are all justified ones
    assert!(report.suppressed >= 5, "suppressed = {}", report.suppressed);
}

#[test]
fn json_report_is_parseable_and_complete() {
    let (findings, _) = audit::scan_source(
        "rust/src/solver/native.rs",
        &fixture("float_reduce.rs"),
    );
    let report = audit::AuditReport { findings, files_scanned: 1, suppressed: 0 };
    let text = audit::render_json(&report);
    let parsed = dapc::config::json::Json::parse(&text).expect("valid json");
    let n = parsed
        .get("findings")
        .and_then(|f| f.as_arr())
        .map(|a| a.len())
        .expect("findings array");
    assert_eq!(n, 2);
}
