//! Message transports: in-process channels (threaded local cluster) and
//! versioned, length-framed TCP streams (multi-process cluster), behind
//! one trait so the leader/worker code is transport-agnostic.
//!
//! Every transport keeps wire-byte counters (frame headers included; the
//! in-process channel reports the bytes an equivalent TCP link would
//! carry) — the `distributed_epoch` bench uses them to prove per-epoch
//! traffic is flat in the epoch count.  Stream frames carry a
//! magic+version header ([`Message`]'s `WIRE_VERSION`) so mixed old/new
//! clusters fail loudly at the first frame instead of mis-decoding.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;

use crate::error::{DapcError, Result};

use super::message::{Message, WIRE_VERSION};

/// Frame header: "DP" magic in the high half, wire version in the low.
const FRAME_MAGIC: u32 = 0x4450_0000;
const FRAME_MAGIC_MASK: u32 = 0xFFFF_0000;
/// Bytes of framing per message (u32 header + u32 payload length).
pub const FRAME_OVERHEAD: u64 = 8;

fn frame_header() -> u32 {
    FRAME_MAGIC | WIRE_VERSION
}

/// Bidirectional message endpoint.
pub trait Transport: Send {
    fn send(&mut self, msg: &Message) -> Result<()>;
    fn recv(&mut self) -> Result<Message>;

    /// Non-blocking receive: `Ok(None)` when no complete message is
    /// ready yet.  The default falls back to blocking, which degrades
    /// out-of-order gathers to in-order ones but stays correct.
    fn try_recv(&mut self) -> Result<Option<Message>> {
        self.recv().map(Some)
    }

    /// Wire bytes sent so far (payload + framing).
    fn bytes_sent(&self) -> u64 {
        0
    }

    /// Wire bytes received so far (payload + framing).
    fn bytes_received(&self) -> u64 {
        0
    }
}

// --- in-process -------------------------------------------------------------

/// One side of an in-process duplex channel.
pub struct ChannelTransport {
    tx: mpsc::Sender<Message>,
    rx: mpsc::Receiver<Message>,
    bytes_tx: u64,
    bytes_rx: u64,
}

/// Create a connected pair (leader side, worker side).
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (tx_a, rx_b) = mpsc::channel();
    let (tx_b, rx_a) = mpsc::channel();
    (
        ChannelTransport { tx: tx_a, rx: rx_a, bytes_tx: 0, bytes_rx: 0 },
        ChannelTransport { tx: tx_b, rx: rx_b, bytes_tx: 0, bytes_rx: 0 },
    )
}

impl ChannelTransport {
    fn wire_size(msg: &Message) -> u64 {
        msg.encoded_len() as u64 + FRAME_OVERHEAD
    }
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        self.bytes_tx += Self::wire_size(msg);
        self.tx
            .send(msg.clone())
            .map_err(|_| DapcError::Coordinator("peer hung up".into()))
    }

    fn recv(&mut self) -> Result<Message> {
        let msg = self
            .rx
            .recv()
            .map_err(|_| DapcError::Coordinator("peer hung up".into()))?;
        self.bytes_rx += Self::wire_size(&msg);
        Ok(msg)
    }

    fn try_recv(&mut self) -> Result<Option<Message>> {
        match self.rx.try_recv() {
            Ok(msg) => {
                self.bytes_rx += Self::wire_size(&msg);
                Ok(Some(msg))
            }
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => {
                Err(DapcError::Coordinator("peer hung up".into()))
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_tx
    }

    fn bytes_received(&self) -> u64 {
        self.bytes_rx
    }
}

// --- TCP --------------------------------------------------------------------

const HEADER_LEN: usize = FRAME_OVERHEAD as usize;

/// Scratch capacity retained between frames.  Only the one-time
/// `InitPartition` frame carries a dense block (O(l·n) bytes); keeping
/// that much scratch alive for the whole solve would breach the leader's
/// O(n)-state guarantee, so after a small frame any oversized buffer is
/// released.  Steady-state frames larger than this keep their buffer —
/// reuse stays allocation-free where it matters.
const SCRATCH_RETAIN_LIMIT: usize = 64 * 1024;

/// Versioned length-framed messages over a TCP stream
/// (`u32 LE magic|version | u32 LE payload_len | payload`).
///
/// Send and receive each reuse one internal scratch buffer, so the
/// steady-state epoch traffic allocates nothing at the byte layer; the
/// incremental receive state machine supports [`Transport::try_recv`]
/// (partial frames persist across calls until complete).
pub struct TcpTransport {
    stream: TcpStream,
    send_buf: Vec<u8>,
    /// Receive scratch: header then payload, filled incrementally.
    recv_buf: Vec<u8>,
    recv_filled: usize,
    recv_target: usize,
    header_parsed: bool,
    nonblocking: bool,
    bytes_tx: u64,
    bytes_rx: u64,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream
            .set_nodelay(true)
            .map_err(|e| DapcError::Coordinator(e.to_string()))?;
        Ok(Self {
            stream,
            send_buf: Vec::new(),
            recv_buf: vec![0u8; HEADER_LEN],
            recv_filled: 0,
            recv_target: HEADER_LEN,
            header_parsed: false,
            nonblocking: false,
            bytes_tx: 0,
            bytes_rx: 0,
        })
    }

    fn set_blocking(&mut self, blocking: bool) -> Result<()> {
        if self.nonblocking == !blocking {
            return Ok(());
        }
        self.stream
            .set_nonblocking(!blocking)
            .map_err(|e| DapcError::Coordinator(e.to_string()))?;
        self.nonblocking = !blocking;
        Ok(())
    }

    /// Validate the frame header and switch the state machine to the
    /// payload phase.
    fn parse_header(&mut self) -> Result<()> {
        let hdr =
            u32::from_le_bytes(self.recv_buf[0..4].try_into().unwrap());
        if hdr & FRAME_MAGIC_MASK != FRAME_MAGIC {
            return Err(DapcError::Coordinator(format!(
                "bad frame header {hdr:#010x}: peer is not speaking the \
                 DAPC v{WIRE_VERSION} wire protocol (old unversioned peer, \
                 or not a dapc worker at all)"
            )));
        }
        let ver = hdr & !FRAME_MAGIC_MASK;
        if ver != WIRE_VERSION {
            return Err(DapcError::Coordinator(format!(
                "peer speaks wire protocol v{ver}, this build speaks \
                 v{WIRE_VERSION}: upgrade the older side of the cluster"
            )));
        }
        let len =
            u32::from_le_bytes(self.recv_buf[4..8].try_into().unwrap())
                as usize;
        // guard against absurd frames (corrupted stream)
        if len > 1 << 30 {
            return Err(DapcError::Coordinator(format!(
                "frame length {len} exceeds 1 GiB sanity limit"
            )));
        }
        self.recv_target = HEADER_LEN + len;
        if self.recv_buf.len() < self.recv_target {
            self.recv_buf.resize(self.recv_target, 0);
        }
        self.header_parsed = true;
        Ok(())
    }

    /// Pump the receive state machine.  `blocking = false` returns
    /// `Ok(None)` as soon as the socket has no more bytes, preserving the
    /// partial frame for the next call.
    fn pump_recv(&mut self, blocking: bool) -> Result<Option<Message>> {
        self.set_blocking(blocking)?;
        loop {
            if self.recv_filled == self.recv_target {
                if !self.header_parsed {
                    self.parse_header()?;
                    continue;
                }
                let msg = Message::decode(
                    &self.recv_buf[HEADER_LEN..self.recv_target],
                )?;
                self.bytes_rx += self.recv_target as u64;
                let frame_len = self.recv_target;
                self.recv_filled = 0;
                self.recv_target = HEADER_LEN;
                self.header_parsed = false;
                if frame_len <= SCRATCH_RETAIN_LIMIT
                    && self.recv_buf.capacity() > SCRATCH_RETAIN_LIMIT
                {
                    // drop capacity left over from an oversized earlier
                    // frame (the init block)
                    self.recv_buf.truncate(HEADER_LEN);
                    self.recv_buf.shrink_to(SCRATCH_RETAIN_LIMIT);
                }
                return Ok(Some(msg));
            }
            match self
                .stream
                .read(&mut self.recv_buf[self.recv_filled..self.recv_target])
            {
                Ok(0) => {
                    return Err(DapcError::Coordinator(
                        "connection closed by peer".into(),
                    ))
                }
                Ok(k) => self.recv_filled += k,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if blocking {
                        // read timeouts surface as WouldBlock even on
                        // blocking sockets; none are set here, but be safe
                        continue;
                    }
                    return Ok(None);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        self.set_blocking(true)?;
        self.send_buf.clear();
        self.send_buf.extend_from_slice(&frame_header().to_le_bytes());
        self.send_buf.extend_from_slice(&[0u8; 4]); // length placeholder
        msg.encode_into(&mut self.send_buf);
        let len = (self.send_buf.len() - HEADER_LEN) as u32;
        self.send_buf[4..8].copy_from_slice(&len.to_le_bytes());
        self.stream.write_all(&self.send_buf)?;
        self.stream.flush()?;
        self.bytes_tx += self.send_buf.len() as u64;
        if self.send_buf.len() <= SCRATCH_RETAIN_LIMIT
            && self.send_buf.capacity() > SCRATCH_RETAIN_LIMIT
        {
            // capacity left over from the one-shot oversized init frame:
            // don't pin a block-sized buffer (O(l·n) per link) for the
            // rest of the solve
            self.send_buf.clear();
            self.send_buf.shrink_to(SCRATCH_RETAIN_LIMIT);
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        match self.pump_recv(true)? {
            Some(msg) => Ok(msg),
            None => unreachable!("blocking pump always yields a frame"),
        }
    }

    fn try_recv(&mut self) -> Result<Option<Message>> {
        self.pump_recv(false)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_tx
    }

    fn bytes_received(&self) -> u64 {
        self.bytes_rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn channel_pair_duplex() {
        let (mut a, mut b) = channel_pair();
        a.send(&Message::Shutdown).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Shutdown);
        b.send(&Message::InitDone { worker_id: 1, x0: vec![1.0] }).unwrap();
        assert_eq!(
            a.recv().unwrap(),
            Message::InitDone { worker_id: 1, x0: vec![1.0] }
        );
    }

    #[test]
    fn channel_detects_hangup() {
        let (mut a, b) = channel_pair();
        drop(b);
        assert!(a.send(&Message::Shutdown).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn channel_try_recv_and_byte_accounting() {
        let (mut a, mut b) = channel_pair();
        assert_eq!(a.try_recv().unwrap(), None);
        let msg = Message::UpdateDone { worker_id: 0, x: vec![1.0, 2.0] };
        b.send(&msg).unwrap();
        assert_eq!(a.try_recv().unwrap(), Some(msg.clone()));
        let wire = msg.encoded_len() as u64 + FRAME_OVERHEAD;
        assert_eq!(b.bytes_sent(), wire);
        assert_eq!(a.bytes_received(), wire);
        assert_eq!(a.bytes_sent(), 0);
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        let msg = Message::RunUpdate {
            epoch: 5,
            gamma: 0.5,
            xbar: (0..100).map(|i| i as f32).collect(),
        };
        client.send(&msg).unwrap();
        assert_eq!(client.recv().unwrap(), msg);
        server.join().unwrap();
        // framing accounted on both directions
        let wire = msg.encoded_len() as u64 + FRAME_OVERHEAD;
        assert_eq!(client.bytes_sent(), wire);
        assert_eq!(client.bytes_received(), wire);
    }

    #[test]
    fn tcp_try_recv_returns_none_then_message() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            // wait for the go signal, then reply
            let _ = t.recv().unwrap();
            t.send(&Message::Shutdown).unwrap();
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        // nothing sent yet: try_recv must not block or error
        assert_eq!(client.try_recv().unwrap(), None);
        client.send(&Message::Shutdown).unwrap();
        // poll until the echo arrives (partial frames handled internally)
        let msg = loop {
            if let Some(m) = client.try_recv().unwrap() {
                break m;
            }
            std::thread::yield_now();
        };
        assert_eq!(msg, Message::Shutdown);
        server.join().unwrap();
    }

    #[test]
    fn tcp_detects_closed_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            drop(stream); // close immediately
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        server.join().unwrap();
        assert!(client.recv().is_err());
    }

    #[test]
    fn unversioned_peer_rejected_loudly() {
        // an old (pre-versioning) peer sends `u32 len | payload`; the
        // first 4 bytes a v2 receiver sees are a small length, which can
        // never carry the DP magic -> loud protocol error, no mis-decode
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // > 8 bytes total so the receiver can fill its header buffer
            let payload =
                Message::InitDone { worker_id: 1, x0: vec![1.0, 2.0] }.encode();
            stream
                .write_all(&(payload.len() as u32).to_le_bytes())
                .unwrap();
            stream.write_all(&payload).unwrap();
            stream.flush().unwrap();
            // hold the socket open until the client has judged the frame
            let mut sink = [0u8; 1];
            let _ = stream.read(&mut sink);
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        let err = client.recv().unwrap_err();
        let text = err.to_string();
        assert!(text.contains("wire protocol"), "unexpected error: {text}");
        drop(client);
        server.join().unwrap();
    }

    #[test]
    fn v3_peer_rejected_by_v4_build() {
        // a pre-telemetry (v3) worker connecting to this (v4) build must
        // die at the first frame with an actionable message, never reach
        // Message::decode
        assert!(WIRE_VERSION >= 4, "test assumes the v4 telemetry bump");
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let payload = Message::Shutdown.encode();
            let v3_header = FRAME_MAGIC | 3;
            stream.write_all(&v3_header.to_le_bytes()).unwrap();
            stream
                .write_all(&(payload.len() as u32).to_le_bytes())
                .unwrap();
            stream.write_all(&payload).unwrap();
            stream.flush().unwrap();
            let mut sink = [0u8; 1];
            let _ = stream.read(&mut sink);
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        let err = client.recv().unwrap_err().to_string();
        assert!(err.contains("v3"), "unexpected error: {err}");
        assert!(err.contains("upgrade"), "unexpected error: {err}");
        drop(client);
        server.join().unwrap();
    }

    #[test]
    fn v4_peer_rejected_by_v5_build() {
        // a pre-multi-tenant (v4) peer connecting to this (v5) build must
        // die at the first frame — its session frames have no
        // session_id/request_id and would otherwise mis-decode
        assert!(WIRE_VERSION >= 5, "test assumes the v5 multi-tenant bump");
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let payload = Message::Shutdown.encode();
            let v4_header = FRAME_MAGIC | 4;
            stream.write_all(&v4_header.to_le_bytes()).unwrap();
            stream
                .write_all(&(payload.len() as u32).to_le_bytes())
                .unwrap();
            stream.write_all(&payload).unwrap();
            stream.flush().unwrap();
            let mut sink = [0u8; 1];
            let _ = stream.read(&mut sink);
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        let err = client.recv().unwrap_err().to_string();
        assert!(err.contains("v4"), "unexpected error: {err}");
        assert!(err.contains("upgrade"), "unexpected error: {err}");
        drop(client);
        server.join().unwrap();
    }

    #[test]
    fn wrong_version_rejected_loudly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let payload = Message::Shutdown.encode();
            let bad_header = FRAME_MAGIC | (WIRE_VERSION + 1);
            stream.write_all(&bad_header.to_le_bytes()).unwrap();
            stream
                .write_all(&(payload.len() as u32).to_le_bytes())
                .unwrap();
            stream.write_all(&payload).unwrap();
            stream.flush().unwrap();
            let mut sink = [0u8; 1];
            let _ = stream.read(&mut sink);
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        let err = client.recv().unwrap_err().to_string();
        assert!(err.contains("upgrade"), "unexpected error: {err}");
        drop(client);
        server.join().unwrap();
    }
}
