//! The unified consensus driver: ONE epoch loop for every deployment
//! topology.
//!
//! The paper's algorithm (eqs. (5)-(7)) is topology-independent: the same
//! iteration runs on a laptop and on a cluster, only *where* the per-
//! partition work executes changes.  This module encodes that split:
//!
//! * [`ConsensusBackend`] — the topology: where partitions live and how a
//!   round's estimates come back.  [`InProcessBackend`] executes on a
//!   [`ComputeEngine`] in this process through the allocation-free
//!   `round_into`/[`RoundWorkspace`] path; `coordinator::ClusterBackend`
//!   scatters over transports to remote workers.
//! * [`drive_apc`] / [`drive_dgd`] — the algorithm: eq. (5) seeding,
//!   eq. (7) mixing, the DGD step, convergence tracing, phase timing and
//!   [`SolveReport`] assembly live HERE, once.  Backends never duplicate
//!   the epoch loop.
//!
//! Numerical contract: a backend either returns its round through the
//! streaming f64 accumulator (`acc[i] = sum_j x_j[i]`, partitions summed
//! in fixed order `j = 0..J`) and lets the driver apply eq. (7), or mixes
//! in place via an engine whose averaging kernel is the *same* fixed-order
//! f64 reduction (`engine::average_chunk_kernel`).  Either way
//! every backend produces bit-identical iterates — the property
//! `tests/distributed_equivalence.rs` locks in.
//!
//! When metrics are enabled ([`crate::obs`]) the loop also feeds the
//! `driver.seed_ns` / `driver.update_ns` / `driver.mix_ns` phase
//! histograms.  Instrumentation wraps the phases — it never reaches into
//! the kernels — so iterates are bitwise identical with metrics on or
//! off (`tests/observability.rs` pins this).

use std::time::Instant;

use crate::error::{DapcError, Result};
use crate::linalg::{blas, norms, Matrix};
use crate::metrics::ConvergenceTrace;
use crate::obs;
use crate::partition::{PartitionPlan, PartitionRegime};
use crate::sparse::CsrMatrix;

use super::consensus::ApcVariant;
use super::engine::{ComputeEngine, InitKind, RoundWorkspace, SeedFactors};
use super::report::{residual_norm, SolveOptions, SolveReport};

/// How a backend returned the consensus round to the driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundOutcome {
    /// `acc` holds `sum_j x_j(t+1)` (fixed order `j = 0..J`, f64); the
    /// driver applies the eq. (7) mixing.
    Accumulated,
    /// The backend already wrote `xbar(t+1)` in place through an engine
    /// whose fused round includes the identical eq. (7) reduction.
    Mixed,
}

/// Where the per-partition work of Algorithm 1 executes.
///
/// Implementations hold all per-partition state (estimates, projectors or
/// the dense blocks) so the driver only ever owns n-length vectors — the
/// paper's leader-side memory guarantee.
pub trait ConsensusBackend {
    /// Number of partitions / workers J this backend drives.
    fn partitions(&self) -> usize;

    /// Algorithm 1 steps 1-4: distribute the `plan`'s blocks, run the
    /// per-partition init (`kind`), and leave `acc[i] = sum_j x_j(0)[i]`
    /// (fixed order, f64).  Returns the solution width the consensus loop
    /// runs at (`>= plan.n` when the engine pads to shape buckets);
    /// `acc` is resized to that width.
    fn init_partitions(
        &mut self,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
        acc: &mut Vec<f64>,
    ) -> Result<usize>;

    /// One eq. (6) round at the current `xbar` across all partitions.
    /// On [`RoundOutcome::Accumulated`] the backend has overwritten `acc`
    /// with the fixed-order sum of the updated estimates; on
    /// [`RoundOutcome::Mixed`] it has written `xbar(t+1)` into `xbar`.
    fn run_round(
        &mut self,
        gamma: f32,
        eta: f32,
        xbar: &mut [f32],
        acc: &mut [f64],
    ) -> Result<RoundOutcome>;

    /// Run all `epochs` rounds in one fused call when the backend's
    /// engine supports it (e.g. the XLA whole-loop artifact), writing the
    /// final average into `xbar`.  `Ok(false)` = not supported, drive the
    /// per-round loop instead.
    fn try_solve_loop(
        &mut self,
        _gamma: f32,
        _eta: f32,
        _epochs: usize,
        _xbar: &mut [f32],
    ) -> Result<bool> {
        Ok(false)
    }

    /// DGD setup: distribute the `plan`'s blocks withOUT any
    /// factorization (workers only need `A_j`, `b_j` for gradients).
    fn init_grad(
        &mut self,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()>;

    /// One DGD gradient round at `x`: overwrite `acc` with
    /// `sum_j A_j^T (A_j x - b_j)` (fixed order, f64).
    fn grad_round(&mut self, x: &[f32], acc: &mut [f64]) -> Result<()>;

    /// Per-partition estimates after the last round (only called when
    /// [`SolveOptions::collect_x_parts`] asks for them).
    fn x_parts(&mut self) -> Result<Vec<Vec<f32>>>;

    /// Engine label for [`SolveReport::engine`].
    fn backend_name(&self) -> &'static str;
}

/// Overwrite `acc` with the fixed-order f64 sum of the estimates.  This
/// is the first half of `engine::average_chunk_kernel`; keeping the
/// identical j-order keeps backends bit-identical.
pub(crate) fn accumulate_sum(xs: &[Vec<f32>], acc: &mut [f64]) {
    for a in acc.iter_mut() {
        *a = 0.0;
    }
    for x in xs {
        for (a, &v) in acc.iter_mut().zip(x.iter()) {
            *a += v as f64;
        }
    }
}

/// Multi-column twin of [`accumulate_sum`]: `accs[c][i] = sum_j
/// xs[j][c][i]`, partitions summed in fixed order `j = 0..J` per column.
pub(crate) fn accumulate_sum_batch(
    xs: &[Vec<Vec<f32>>],
    accs: &mut [Vec<f64>],
) {
    for acc in accs.iter_mut() {
        acc.fill(0.0);
    }
    for xj in xs {
        for (acc, x) in accs.iter_mut().zip(xj.iter()) {
            for (a, &v) in acc.iter_mut().zip(x.iter()) {
                *a += v as f64;
            }
        }
    }
}

/// Eq. (7) in place: `xbar[i] = eta * (acc[i] / J) + (1 - eta) * xbar[i]`
/// — the second half of `engine::average_chunk_kernel`, same f64
/// arithmetic, so driver-side mixing is bit-identical to engine-side.
fn mix_into(acc: &[f64], j: usize, eta: f32, xbar: &mut [f32]) {
    let jf = j as f64;
    let eta = eta as f64;
    for (xb, &a) in xbar.iter_mut().zip(acc.iter()) {
        *xb = (eta * (a / jf) + (1.0 - eta) * *xb as f64) as f32;
    }
}

/// Eq. (5) from the init accumulator: `xbar(0)[i] = acc[i] / J`.
fn mean_from_acc(acc: &[f64], j: usize) -> Vec<f32> {
    let jf = j as f64;
    acc.iter().map(|&s| (s / jf) as f32).collect()
}

pub(crate) fn apc_label(variant: ApcVariant) -> &'static str {
    match variant {
        ApcVariant::Decomposed => "dapc-decomposed",
        ApcVariant::Classical => "apc-classical",
    }
}

fn check_shapes(a: &CsrMatrix, b: &[f32], j: usize) -> Result<(usize, usize)> {
    if j == 0 {
        return Err(DapcError::Coordinator(
            "consensus driver needs at least one partition/worker (got 0)"
                .into(),
        ));
    }
    let (m, n) = a.shape();
    if b.len() != m {
        return Err(DapcError::Shape(format!(
            "rhs length {} != matrix rows {m}",
            b.len()
        )));
    }
    Ok((m, n))
}

/// Full Algorithm 1 over any backend: partition -> init -> consensus.
///
/// This is THE apc epoch loop — `DapcSolver`/`ApcClassicalSolver` run it
/// over [`InProcessBackend`], `coordinator::Leader` over
/// `ClusterBackend`.
/// The worker init matching an APC variant in a partition regime —
/// shared by the cold driver and warm-session registration so both
/// always factorize identically (a divergence here would break the
/// warm == cold bit-identity contract).
pub fn init_kind_for(variant: ApcVariant, regime: PartitionRegime) -> InitKind {
    match (variant, regime) {
        (_, PartitionRegime::Fat) => InitKind::Fat,
        (ApcVariant::Decomposed, PartitionRegime::Tall) => InitKind::Qr,
        (ApcVariant::Classical, PartitionRegime::Tall) => InitKind::Classical,
    }
}

pub fn drive_apc<B: ConsensusBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    b: &[f32],
    variant: ApcVariant,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let j = backend.partitions();
    let (m, n) = check_shapes(a, b, j)?;
    let plan = PartitionPlan::contiguous(m, n, j)?;
    let init_kind = init_kind_for(variant, plan.regime);

    // phase histograms resolved once per solve; recording is lock-free
    // and a no-op when metrics are disabled
    let obs_seed = obs::histogram("driver.seed_ns");
    let obs_update = obs::histogram("driver.update_ns");
    let obs_mix = obs::histogram("driver.mix_ns");

    // ---- init phase (Algorithm 1 steps 1-4) -----------------------------
    let t0 = Instant::now();
    let ot = obs::now();
    let mut acc: Vec<f64> = Vec::new();
    let n_target = backend.init_partitions(init_kind, &plan, a, b, &mut acc)?;
    debug_assert_eq!(acc.len(), n_target);
    // eq. (5): xbar(0) = mean of initial estimates
    let mut xbar = mean_from_acc(&acc, j);
    obs::record_since(&obs_seed, ot);
    let init_time = t0.elapsed();

    // ---- iterate phase (steps 5-8) --------------------------------------
    let algorithm = apc_label(variant);
    let t1 = Instant::now();
    let mut trace = opts.x_true.as_ref().map(|xt| {
        let mut tr = ConvergenceTrace::new(algorithm);
        tr.push(0, norms::mse(&xbar[..xt.len().min(xbar.len())], xt));
        tr
    });

    let fused = opts.fused_loop
        && trace.is_none()
        && backend.try_solve_loop(opts.gamma, opts.eta, opts.epochs, &mut xbar)?;
    if !fused {
        for t in 0..opts.epochs {
            let ot = obs::now();
            match backend.run_round(opts.gamma, opts.eta, &mut xbar, &mut acc)? {
                RoundOutcome::Accumulated => {
                    obs::record_since(&obs_update, ot);
                    let om = obs::now();
                    mix_into(&acc, j, opts.eta, &mut xbar);
                    obs::record_since(&obs_mix, om);
                }
                // the backend's fused round already mixed eq. (7)
                RoundOutcome::Mixed => obs::record_since(&obs_update, ot),
            }
            if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
                tr.push(t + 1, norms::mse(&xbar[..xt.len().min(xbar.len())], xt));
            }
        }
    }
    let iterate_time = t1.elapsed();

    // strip any bucket padding
    xbar.truncate(n);
    let residual = residual_norm(a, b, &xbar);
    let x_parts = if opts.collect_x_parts {
        let mut parts = backend.x_parts()?;
        for x in &mut parts {
            x.truncate(n);
        }
        parts
    } else {
        Vec::new()
    };

    Ok(SolveReport {
        xbar,
        x_parts,
        trace,
        residual: Some(residual),
        init_time,
        iterate_time,
        algorithm,
        engine: backend.backend_name(),
        epochs: opts.epochs,
    })
}

/// Conservative DGD step from the Gershgorin-style bound on
/// `lambda_max(A^T A)` via column squared norms — one implementation for
/// every backend (the leader always holds the CSR matrix).
pub fn auto_dgd_step(a: &CsrMatrix) -> f32 {
    let (m, n) = a.shape();
    let mut colsq = vec![0.0f64; n];
    for r in 0..m {
        let (cols, vals) = a.row(r);
        for (c, v) in cols.iter().zip(vals) {
            colsq[*c] += (*v as f64) * (*v as f64);
        }
    }
    let total: f64 = colsq.iter().sum();
    (1.0 / total.max(1e-12)) as f32
}

/// Distributed gradient descent over any backend — the same partition
/// layout and gather as APC so the Fig. 2 comparison is apples-to-apples.
pub fn drive_dgd<B: ConsensusBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    b: &[f32],
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let j = backend.partitions();
    let (m, n) = check_shapes(a, b, j)?;
    let plan = PartitionPlan::contiguous(m, n, j)?;

    let obs_seed = obs::histogram("driver.seed_ns");
    let obs_update = obs::histogram("driver.update_ns");
    let obs_mix = obs::histogram("driver.mix_ns");

    let t0 = Instant::now();
    let ot = obs::now();
    backend.init_grad(&plan, a, b)?;
    let alpha = if opts.dgd_step > 0.0 {
        opts.dgd_step
    } else {
        auto_dgd_step(a)
    };
    let mut x = vec![0.0f32; n];
    obs::record_since(&obs_seed, ot);
    let init_time = t0.elapsed();

    let mut trace = opts.x_true.as_ref().map(|xt| {
        let mut tr = ConvergenceTrace::new("dgd");
        tr.push(0, norms::mse(&x, xt));
        tr
    });

    let t1 = Instant::now();
    let mut acc = vec![0.0f64; n];
    for t in 0..opts.epochs {
        let ot = obs::now();
        backend.grad_round(&x, &mut acc)?;
        obs::record_since(&obs_update, ot);
        let om = obs::now();
        for (xi, g) in x.iter_mut().zip(&acc) {
            *xi -= alpha * (*g as f32);
        }
        obs::record_since(&obs_mix, om);
        if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
            tr.push(t + 1, norms::mse(&x, xt));
        }
    }
    let iterate_time = t1.elapsed();
    let residual = residual_norm(a, b, &x);

    let x_parts = if opts.collect_x_parts {
        vec![x.clone()]
    } else {
        Vec::new()
    };
    Ok(SolveReport {
        xbar: x,
        x_parts,
        trace,
        residual: Some(residual),
        init_time,
        iterate_time,
        algorithm: "dgd",
        engine: backend.backend_name(),
        epochs: opts.epochs,
    })
}

// ---------------------------------------------------------------------------
// Warm sessions: register once, stream right-hand sides
// ---------------------------------------------------------------------------

/// Opaque id naming one registered matrix (a *session*) on a backend.
/// Allocated by the service layer (`service::SessionManager`), carried
/// on every v5 session wire frame, and meaningful to workers: one
/// worker holds MANY resident factorizations keyed by session id.
pub type SessionId = u64;

/// Leader-assigned id of one registration/solve request, echoed
/// verbatim in every reply frame it produces (casparianflow-style job
/// ids) — lets a multiplexing leader pair replies with requests.
pub type RequestId = u64;

/// Warm-session capability on a [`ConsensusBackend`]: register a matrix
/// under a [`SessionId`] (partitions factorize and retain
/// `A_j`/`P_j`/seed state for THAT session), then serve an arbitrary
/// stream of right-hand sides against it — per-RHS work is seeding plus
/// the epoch loop, never a second O(l n^2) factorization.  `P_j` is
/// RHS-independent (eqs. (1)-(4) build it from `A_j` alone), so the
/// retained state serves every future `b` unchanged.
///
/// A backend holds MANY sessions at once (multi-tenant service);
/// every method names the session it operates on, and
/// [`Self::unregister_session`] releases one session's resident state
/// (idempotent — the LRU evictor may race a concurrent unregister).
///
/// All methods operate on k >= 1 RHS *columns* at once and keep the base
/// trait's fixed-order f64 reduction contract per column, so warm and
/// batched solves stay bit-identical to cold sequential ones across
/// every backend — with requests interleaved across sessions in any
/// order (`tests/distributed_equivalence.rs` locks this in).
pub trait SessionBackend: ConsensusBackend {
    /// Factorize and retain the plan's blocks (projector + seed state,
    /// both RHS-independent) under `sid`, replacing any state that id
    /// already held.  Returns the solution width the consensus loop
    /// runs at.
    fn register_matrix(
        &mut self,
        sid: SessionId,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<usize>;

    /// Register `sid` for gradient-only (DGD) service: partitions store
    /// their blocks, no factorization at all.
    fn register_grad(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<()>;

    /// Seed `bs.len()` fresh right-hand sides through `sid`'s retained
    /// factorizations: per-partition estimates become `x_j(0)` per
    /// column and `accs[c]` (resized to the session width) receives the
    /// fixed-order f64 sum feeding eq. (5).  Errors loudly when `sid`
    /// has no registered matrix.
    fn seed_rhs(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        bs: &[&[f32]],
        accs: &mut [Vec<f64>],
    ) -> Result<()>;

    /// Store `bs.len()` right-hand sides for gradient service — the DGD
    /// twin of [`Self::seed_rhs`] (no estimates exist; DGD starts at 0).
    fn seed_grad_rhs(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        bs: &[&[f32]],
    ) -> Result<()>;

    /// One eq. (6)/(7) round over every partition and every column
    /// seeded into `sid`; outcome semantics per column match
    /// [`ConsensusBackend::run_round`].
    fn run_round_batch(
        &mut self,
        sid: SessionId,
        gamma: f32,
        eta: f32,
        xbars: &mut [Vec<f32>],
        accs: &mut [Vec<f64>],
    ) -> Result<RoundOutcome>;

    /// One DGD gradient round per column against `sid`:
    /// `accs[c] = sum_j A_j^T (A_j x_c - b_jc)` (fixed order per column).
    fn grad_round_batch(
        &mut self,
        sid: SessionId,
        xs: &[Vec<f32>],
        accs: &mut [Vec<f64>],
    ) -> Result<()>;

    /// Release every resident byte `sid` holds (factorizations, packed
    /// panels, retained blocks).  Idempotent: unknown ids are a no-op —
    /// eviction must be safe to repeat.  The session can be registered
    /// again later under the same id.
    fn unregister_session(&mut self, sid: SessionId) -> Result<()>;
}

/// [`drive_apc`]'s iterate phase generalized to k RHS columns over a
/// warm session: eq. (5) seeds each column's average from its
/// accumulator, then `opts.epochs` batched rounds run with eq. (7)
/// mixed per column.  Column for column this performs exactly the
/// single-RHS loop's arithmetic, so a batch of k is bit-identical to k
/// sequential solves.  Returns the final averages (padded width; the
/// caller truncates).
pub fn drive_apc_epochs_multi<B: SessionBackend + ?Sized>(
    backend: &mut B,
    sid: SessionId,
    accs: &mut [Vec<f64>],
    opts: &SolveOptions,
) -> Result<Vec<Vec<f32>>> {
    let j = backend.partitions();
    let obs_seed = obs::histogram("driver.seed_ns");
    let obs_update = obs::histogram("driver.update_ns");
    let obs_mix = obs::histogram("driver.mix_ns");
    let ot = obs::now();
    let mut xbars: Vec<Vec<f32>> =
        accs.iter().map(|acc| mean_from_acc(acc, j)).collect();
    obs::record_since(&obs_seed, ot);
    for _ in 0..opts.epochs {
        let ot = obs::now();
        match backend
            .run_round_batch(sid, opts.gamma, opts.eta, &mut xbars, accs)?
        {
            RoundOutcome::Accumulated => {
                obs::record_since(&obs_update, ot);
                let om = obs::now();
                for (xbar, acc) in xbars.iter_mut().zip(accs.iter()) {
                    mix_into(acc, j, opts.eta, xbar);
                }
                obs::record_since(&obs_mix, om);
            }
            RoundOutcome::Mixed => obs::record_since(&obs_update, ot),
        }
    }
    Ok(xbars)
}

/// [`drive_dgd`]'s iterate phase generalized to k RHS columns over a
/// warm session (step size `alpha` resolved by the caller, once per
/// session).  Returns the k final iterates.
pub fn drive_dgd_epochs_multi<B: SessionBackend + ?Sized>(
    backend: &mut B,
    sid: SessionId,
    k: usize,
    n: usize,
    alpha: f32,
    epochs: usize,
) -> Result<Vec<Vec<f32>>> {
    let obs_update = obs::histogram("driver.update_ns");
    let obs_mix = obs::histogram("driver.mix_ns");
    let mut xs = vec![vec![0.0f32; n]; k];
    let mut accs = vec![vec![0.0f64; n]; k];
    for _ in 0..epochs {
        let ot = obs::now();
        backend.grad_round_batch(sid, &xs, &mut accs)?;
        obs::record_since(&obs_update, ot);
        let om = obs::now();
        for (x, acc) in xs.iter_mut().zip(accs.iter()) {
            for (xi, g) in x.iter_mut().zip(acc.iter()) {
                *xi -= alpha * (*g as f32);
            }
        }
        obs::record_since(&obs_mix, om);
    }
    Ok(xs)
}

// ---------------------------------------------------------------------------
// In-process backend
// ---------------------------------------------------------------------------

/// Backend executing every partition on a [`ComputeEngine`] in this
/// process.
///
/// The consensus path goes through the engine's
/// [`ComputeEngine::round_into`] with a warmed [`RoundWorkspace`] and
/// double-buffered estimates, so the steady-state epoch loop performs no
/// heap allocations — exactly the PR-1 hot path, now reachable from the
/// shared driver.
pub struct InProcessBackend<'e, E: ComputeEngine> {
    engine: &'e E,
    j: usize,
    // consensus state (filled by init_partitions)
    xs: Vec<Vec<f32>>,
    next_xs: Vec<Vec<f32>>,
    ps: Vec<Matrix>,
    ws: RoundWorkspace,
    next_xbar: Vec<f32>,
    // dgd state (filled by init_grad)
    blocks: Vec<(Matrix, Vec<f32>)>,
    ax: Vec<Vec<f32>>,
    grad: Vec<f32>,
    // warm-session state, keyed by session id (multi-tenant service):
    // each session's dense blocks + seed factorizations + prepacked
    // projector panels stay resident so every later rhs pays only
    // O(l n + n^2) seeding, and every epoch runs the packed wide-gemm
    // sweep with no per-epoch packing or widening.  BTreeMap for the
    // audit no-hashmap rule AND deterministic iteration order.
    sessions: std::collections::BTreeMap<SessionId, InProcSession>,
}

/// One registered session's resident state on [`InProcessBackend`].
struct InProcSession {
    // APC state (empty for gradient-only sessions)
    ps: Vec<Matrix>,
    seeds: Vec<SeedFactors>,
    packs: Vec<blas::PrepackedPanels>,
    // retained dense blocks (seeding + DGD gradients)
    blocks: Vec<Matrix>,
    // DGD: per-partition, per-column rhs slices + gradient scratch
    bs: Vec<Vec<Vec<f32>>>,
    ax: Vec<Vec<f32>>,
    grad: Vec<f32>,
    // seeded batch iterate state (double-buffered)
    batch_xs: Vec<Vec<Vec<f32>>>,
    batch_next_xs: Vec<Vec<Vec<f32>>>,
    next_xbars: Vec<Vec<f32>>,
    n: usize,
}

impl<'e, E: ComputeEngine> InProcessBackend<'e, E> {
    /// Backend over `engine` splitting the system into `j` partitions.
    pub fn new(engine: &'e E, j: usize) -> Self {
        Self {
            engine,
            j,
            xs: Vec::new(),
            next_xs: Vec::new(),
            ps: Vec::new(),
            ws: RoundWorkspace::default(),
            next_xbar: Vec::new(),
            blocks: Vec::new(),
            ax: Vec::new(),
            grad: Vec::new(),
            sessions: std::collections::BTreeMap::new(),
        }
    }

    fn check_plan(&self, plan: &PartitionPlan) -> Result<()> {
        if plan.j() != self.j {
            return Err(DapcError::Shape(format!(
                "plan has {} blocks for a {}-partition backend",
                plan.j(),
                self.j
            )));
        }
        Ok(())
    }
}

/// The loud unknown-session error every backend raises when an RHS
/// names a session that was never registered (or has been evicted).
fn unknown_session(sid: SessionId, what: &str, want: &str) -> DapcError {
    DapcError::Coordinator(format!(
        "session {sid}: {what} before {want}: register a matrix into the \
         session before streaming right-hand sides"
    ))
}

impl<E: ComputeEngine> ConsensusBackend for InProcessBackend<'_, E> {
    fn partitions(&self) -> usize {
        self.j
    }

    fn init_partitions(
        &mut self,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
        acc: &mut Vec<f64>,
    ) -> Result<usize> {
        let j = self.j;
        // engines may pad to a bucket; all partitions must agree on the
        // target width
        let max_rows = plan.blocks.iter().map(|blk| blk.len()).max().unwrap();
        let n_target = self
            .engine
            .init_bucket(kind, max_rows, plan.n)?
            .map(|(_, np)| np)
            .unwrap_or(plan.n);
        // blocks are densified on demand inside init_all: the sequential
        // engine holds one at a time (unchanged peak memory), the parallel
        // engine extracts + factorizes partitions concurrently
        let inits =
            self.engine
                .init_all(kind, j, &|i| plan.extract(a, b, i), n_target)?;
        self.xs = inits.iter().map(|w| w.x0.clone()).collect();
        // cold one-shot solves keep the row-dot round over `self.ps`;
        // session state (prepacked panels included) lives per-session in
        // `self.sessions` and can never be paired with these projectors
        self.ps = inits.into_iter().map(|w| w.projector).collect();
        self.next_xs =
            self.xs.iter().map(|x| vec![0.0f32; x.len()]).collect();
        self.next_xbar = vec![0.0f32; n_target];
        self.ws.ensure(j, n_target);
        acc.clear();
        acc.resize(n_target, 0.0);
        accumulate_sum(&self.xs, acc);
        Ok(n_target)
    }

    fn run_round(
        &mut self,
        gamma: f32,
        eta: f32,
        xbar: &mut [f32],
        _acc: &mut [f64],
    ) -> Result<RoundOutcome> {
        // allocation-free: warmed workspace + double-buffered estimates
        self.engine.round_into(
            &self.xs,
            xbar,
            &self.ps,
            gamma,
            eta,
            &mut self.ws,
            &mut self.next_xs,
            &mut self.next_xbar,
        )?;
        std::mem::swap(&mut self.xs, &mut self.next_xs);
        xbar.copy_from_slice(&self.next_xbar);
        Ok(RoundOutcome::Mixed)
    }

    fn try_solve_loop(
        &mut self,
        gamma: f32,
        eta: f32,
        epochs: usize,
        xbar: &mut [f32],
    ) -> Result<bool> {
        match self
            .engine
            .solve_loop(&self.xs, xbar, &self.ps, gamma, eta, epochs)?
        {
            Some((new_xs, new_xbar)) => {
                self.xs = new_xs;
                xbar.copy_from_slice(&new_xbar);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    fn init_grad(
        &mut self,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()> {
        self.blocks = (0..self.j).map(|i| plan.extract(a, b, i)).collect();
        self.ax = self
            .blocks
            .iter()
            .map(|(sub, _)| vec![0.0f32; sub.rows()])
            .collect();
        self.grad = vec![0.0f32; plan.n];
        Ok(())
    }

    fn grad_round(&mut self, x: &[f32], acc: &mut [f64]) -> Result<()> {
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        for ((sub, rhs), ax) in self.blocks.iter().zip(self.ax.iter_mut()) {
            self.engine.dgd_grad_into(sub, x, rhs, ax, &mut self.grad)?;
            for (a, g) in acc.iter_mut().zip(&self.grad) {
                *a += *g as f64;
            }
        }
        Ok(())
    }

    fn x_parts(&mut self) -> Result<Vec<Vec<f32>>> {
        Ok(self.xs.clone())
    }

    fn backend_name(&self) -> &'static str {
        self.engine.name()
    }
}

impl<E: ComputeEngine> SessionBackend for InProcessBackend<'_, E> {
    fn register_matrix(
        &mut self,
        sid: SessionId,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<usize> {
        self.check_plan(plan)?;
        let n = plan.n;
        // densify every block up front (sessions retain them for seeding
        // anyway), then factorize in ONE engine-level pass — partition-
        // parallel on pooled engines, with the panel-blocked QR fanning
        // trailing updates when partitions are scarcer than threads
        let blocks: Vec<Matrix> = plan
            .blocks
            .iter()
            .map(|blk| a.slice_rows_dense(blk.start, blk.end))
            .collect();
        let facs = self.engine.factorize_all(kind, &blocks, n)?;
        let mut ps = Vec::with_capacity(self.j);
        let mut seeds = Vec::with_capacity(self.j);
        let mut packs = Vec::with_capacity(self.j);
        for fac in facs {
            ps.push(fac.projector);
            packs.push(fac.panels);
            seeds.push(fac.seed);
        }
        // replaces any state `sid` already held (re-registration after
        // eviction lands here too)
        self.sessions.insert(
            sid,
            InProcSession {
                ps,
                seeds,
                packs,
                blocks,
                bs: Vec::new(),
                ax: Vec::new(),
                grad: Vec::new(),
                batch_xs: Vec::new(),
                batch_next_xs: Vec::new(),
                next_xbars: Vec::new(),
                n,
            },
        );
        Ok(n)
    }

    fn register_grad(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<()> {
        self.check_plan(plan)?;
        let blocks: Vec<Matrix> = plan
            .blocks
            .iter()
            .map(|blk| a.slice_rows_dense(blk.start, blk.end))
            .collect();
        let ax = blocks.iter().map(|sub| vec![0.0f32; sub.rows()]).collect();
        self.sessions.insert(
            sid,
            InProcSession {
                ps: Vec::new(),
                seeds: Vec::new(),
                packs: Vec::new(),
                blocks,
                bs: Vec::new(),
                ax,
                grad: vec![0.0f32; plan.n],
                batch_xs: Vec::new(),
                batch_next_xs: Vec::new(),
                next_xbars: Vec::new(),
                n: plan.n,
            },
        );
        Ok(())
    }

    fn seed_rhs(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        bs: &[&[f32]],
        accs: &mut [Vec<f64>],
    ) -> Result<()> {
        let j = self.j;
        let engine = self.engine;
        let sess = match self.sessions.get_mut(&sid) {
            Some(s) if s.seeds.len() == j && j > 0 => s,
            _ => {
                return Err(unknown_session(
                    sid,
                    "seed_rhs",
                    "register_matrix",
                ))
            }
        };
        let m = plan.blocks.last().map(|b| b.end).unwrap_or(0);
        for b in bs {
            if b.len() != m {
                return Err(DapcError::Shape(format!(
                    "rhs length {} != matrix rows {m}",
                    b.len()
                )));
            }
        }
        let k = bs.len();
        let n = sess.n;
        sess.batch_xs.resize_with(j, Vec::new);
        for ((xcols, (seed, sub)), blk) in sess
            .batch_xs
            .iter_mut()
            .zip(sess.seeds.iter().zip(&sess.blocks))
            .zip(&plan.blocks)
        {
            xcols.clear();
            for b in bs {
                xcols.push(engine.seed(seed, sub, &b[blk.start..blk.end])?);
            }
        }
        sess.batch_next_xs = vec![vec![vec![0.0f32; n]; k]; j];
        sess.next_xbars = vec![vec![0.0f32; n]; k];
        for acc in accs.iter_mut() {
            acc.clear();
            acc.resize(n, 0.0);
        }
        accumulate_sum_batch(&sess.batch_xs, accs);
        Ok(())
    }

    fn seed_grad_rhs(
        &mut self,
        sid: SessionId,
        plan: &PartitionPlan,
        bs: &[&[f32]],
    ) -> Result<()> {
        let j = self.j;
        let sess = match self.sessions.get_mut(&sid) {
            Some(s) if s.blocks.len() == j && s.ax.len() == j && j > 0 => s,
            _ => {
                return Err(unknown_session(
                    sid,
                    "seed_grad_rhs",
                    "register_grad",
                ))
            }
        };
        let m = plan.blocks.last().map(|b| b.end).unwrap_or(0);
        for b in bs {
            if b.len() != m {
                return Err(DapcError::Shape(format!(
                    "rhs length {} != matrix rows {m}",
                    b.len()
                )));
            }
        }
        sess.bs = plan
            .blocks
            .iter()
            .map(|blk| {
                bs.iter().map(|b| b[blk.start..blk.end].to_vec()).collect()
            })
            .collect();
        Ok(())
    }

    fn run_round_batch(
        &mut self,
        sid: SessionId,
        gamma: f32,
        eta: f32,
        xbars: &mut [Vec<f32>],
        _accs: &mut [Vec<f64>],
    ) -> Result<RoundOutcome> {
        // allocation-free batched round: warmed (shared) workspace +
        // per-session double buffers, the multi-column twin of
        // `run_round`.  Registered sessions carry prepacked projector
        // panels and take the packed wide-gemm epoch path — bit-identical
        // to the row-dot round, minus the per-epoch widening/matrix
        // traffic.  The workspace is safe to share across sessions: the
        // engine resizes it per call and every kernel overwrites its
        // scratch before reading it.
        let j = self.j;
        let sess = match self.sessions.get_mut(&sid) {
            Some(s) if s.seeds.len() == j && j > 0 => s,
            _ => {
                return Err(unknown_session(
                    sid,
                    "run_round_batch",
                    "register_matrix",
                ))
            }
        };
        if sess.packs.len() == j {
            self.engine.round_batch_packed_into(
                &sess.batch_xs,
                xbars,
                &sess.ps,
                &sess.packs,
                gamma,
                eta,
                &mut self.ws,
                &mut sess.batch_next_xs,
                &mut sess.next_xbars,
            )?;
        } else {
            self.engine.round_batch_into(
                &sess.batch_xs,
                xbars,
                &sess.ps,
                gamma,
                eta,
                &mut self.ws,
                &mut sess.batch_next_xs,
                &mut sess.next_xbars,
            )?;
        }
        std::mem::swap(&mut sess.batch_xs, &mut sess.batch_next_xs);
        for (xbar, next) in xbars.iter_mut().zip(sess.next_xbars.iter()) {
            xbar.copy_from_slice(next);
        }
        Ok(RoundOutcome::Mixed)
    }

    fn grad_round_batch(
        &mut self,
        sid: SessionId,
        xs: &[Vec<f32>],
        accs: &mut [Vec<f64>],
    ) -> Result<()> {
        let j = self.j;
        let engine = self.engine;
        let sess = match self.sessions.get_mut(&sid) {
            Some(s) if s.bs.len() == j => s,
            Some(_) | None => {
                return Err(DapcError::Coordinator(format!(
                    "session {sid}: grad_round_batch before seed_grad_rhs"
                )));
            }
        };
        let k = xs.len();
        if accs.len() != k || sess.bs.iter().any(|bcols| bcols.len() != k) {
            // a zip would silently truncate the wider side and hand the
            // caller all-zero gradients for the dropped columns
            return Err(DapcError::Coordinator(format!(
                "batch width mismatch: {} stored rhs columns / {} \
                 accumulators vs {k} iterates (seed_grad_rhs before \
                 grad_round_batch?)",
                sess.bs.first().map(Vec::len).unwrap_or(0),
                accs.len()
            )));
        }
        for acc in accs.iter_mut() {
            acc.fill(0.0);
        }
        for ((sub, bcols), ax) in
            sess.blocks.iter().zip(&sess.bs).zip(sess.ax.iter_mut())
        {
            for ((x, bcol), acc) in
                xs.iter().zip(bcols.iter()).zip(accs.iter_mut())
            {
                engine.dgd_grad_into(sub, x, bcol, ax, &mut sess.grad)?;
                for (a, g) in acc.iter_mut().zip(&sess.grad) {
                    *a += *g as f64;
                }
            }
        }
        Ok(())
    }

    fn unregister_session(&mut self, sid: SessionId) -> Result<()> {
        self.sessions.remove(&sid);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::engine::NativeEngine;
    use crate::sparse::generate::GeneratorConfig;

    #[test]
    fn zero_partitions_rejected_with_coordinator_error() {
        let e = NativeEngine::new();
        let ds = GeneratorConfig::small_demo(8, 1).generate(1);
        let mut backend = InProcessBackend::new(&e, 0);
        for r in [
            drive_apc(
                &mut backend,
                &ds.matrix,
                &ds.rhs,
                ApcVariant::Decomposed,
                &SolveOptions::default(),
            ),
            drive_dgd(&mut backend, &ds.matrix, &ds.rhs, &SolveOptions::default()),
        ] {
            match r {
                Err(DapcError::Coordinator(msg)) => {
                    assert!(msg.contains("at least one"), "{msg}")
                }
                other => panic!("expected Coordinator error, got {other:?}"),
            }
        }
    }

    #[test]
    fn driver_mix_matches_engine_average_bitwise() {
        // driver-side eq. (7) must be bit-identical to the engine kernel
        let e = NativeEngine::new();
        let mut g = crate::rng::seeded(9);
        let (j, n) = (3usize, 23usize);
        let xs: Vec<Vec<f32>> = (0..j)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let want = e.average(&xs, &xbar, 0.85).unwrap();

        let mut acc = vec![0.0f64; n];
        accumulate_sum(&xs, &mut acc);
        let mut got = xbar.clone();
        mix_into(&acc, j, 0.85, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn x_parts_collected_only_on_request() {
        let ds = GeneratorConfig::small_demo(16, 2).generate(3);
        let e = NativeEngine::new();
        let base = SolveOptions { epochs: 5, ..Default::default() };

        let mut b1 = InProcessBackend::new(&e, 2);
        let without =
            drive_apc(&mut b1, &ds.matrix, &ds.rhs, ApcVariant::Decomposed, &base)
                .unwrap();
        assert!(without.x_parts.is_empty());

        let mut b2 = InProcessBackend::new(&e, 2);
        let with = drive_apc(
            &mut b2,
            &ds.matrix,
            &ds.rhs,
            ApcVariant::Decomposed,
            &SolveOptions { collect_x_parts: true, ..base },
        )
        .unwrap();
        assert_eq!(with.x_parts.len(), 2);
        assert_eq!(with.xbar, without.xbar);
    }

    #[test]
    fn session_seed_before_register_rejected() {
        let e = NativeEngine::new();
        let ds = GeneratorConfig::small_demo(16, 2).generate(7);
        let plan =
            PartitionPlan::contiguous(ds.matrix.rows(), ds.matrix.cols(), 2)
                .unwrap();
        let mut backend = InProcessBackend::new(&e, 2);
        let b = ds.rhs.clone();
        let mut accs = vec![Vec::new()];
        let err = backend.seed_rhs(7, &plan, &[&b], &mut accs).unwrap_err();
        assert!(err.to_string().contains("before register_matrix"), "{err}");
        assert!(err.to_string().contains("session 7"), "{err}");
        let err = backend.seed_grad_rhs(7, &plan, &[&b]).unwrap_err();
        assert!(err.to_string().contains("before register_grad"), "{err}");
    }

    #[test]
    fn session_register_then_multi_epoch_matches_cold_drive() {
        // one-column warm session == cold drive_apc, at the driver level
        let e = NativeEngine::new();
        let ds = GeneratorConfig::small_demo(24, 3).generate(8);
        let opts = SolveOptions { epochs: 12, ..Default::default() };

        let mut cold_backend = InProcessBackend::new(&e, 3);
        let cold = drive_apc(
            &mut cold_backend,
            &ds.matrix,
            &ds.rhs,
            ApcVariant::Decomposed,
            &opts,
        )
        .unwrap();

        let (m, n) = ds.matrix.shape();
        let plan = PartitionPlan::contiguous(m, n, 3).unwrap();
        let mut warm_backend = InProcessBackend::new(&e, 3);
        let width = warm_backend
            .register_matrix(1, InitKind::Qr, &plan, &ds.matrix)
            .unwrap();
        let mut accs = vec![Vec::new()];
        warm_backend.seed_rhs(1, &plan, &[&ds.rhs], &mut accs).unwrap();
        assert_eq!(accs[0].len(), width);
        let mut xbars =
            drive_apc_epochs_multi(&mut warm_backend, 1, &mut accs, &opts)
                .unwrap();
        let mut warm = xbars.pop().unwrap();
        warm.truncate(n);
        assert_eq!(warm, cold.xbar);
    }

    #[test]
    fn unregister_evicts_and_reregistration_recovers_bitwise() {
        // eviction drops the resident state (later rhs rejected loudly);
        // re-registering the SAME matrix under the SAME id reproduces
        // the original solve bit-for-bit — the transparent
        // re-factorization contract the LRU evictor relies on
        let e = NativeEngine::new();
        let ds = GeneratorConfig::small_demo(24, 3).generate(11);
        let opts = SolveOptions { epochs: 9, ..Default::default() };
        let (m, n) = ds.matrix.shape();
        let plan = PartitionPlan::contiguous(m, n, 3).unwrap();
        let mut backend = InProcessBackend::new(&e, 3);

        let solve = |backend: &mut InProcessBackend<NativeEngine>| {
            let mut accs = vec![Vec::new()];
            backend.seed_rhs(5, &plan, &[&ds.rhs], &mut accs).unwrap();
            let mut xbars =
                drive_apc_epochs_multi(backend, 5, &mut accs, &opts).unwrap();
            let mut x = xbars.pop().unwrap();
            x.truncate(n);
            x
        };

        backend.register_matrix(5, InitKind::Qr, &plan, &ds.matrix).unwrap();
        let first = solve(&mut backend);

        backend.unregister_session(5).unwrap();
        // idempotent: evicting an already-gone session is a no-op
        backend.unregister_session(5).unwrap();
        let mut accs = vec![Vec::new()];
        let err =
            backend.seed_rhs(5, &plan, &[&ds.rhs], &mut accs).unwrap_err();
        assert!(err.to_string().contains("before register_matrix"), "{err}");

        backend.register_matrix(5, InitKind::Qr, &plan, &ds.matrix).unwrap();
        assert_eq!(solve(&mut backend), first);
    }

    #[test]
    fn interleaved_sessions_match_isolated_sessions_bitwise() {
        // two sessions with DIFFERENT matrices, their epoch loops driven
        // through one backend in interleaved order, must produce exactly
        // what each session produces alone — per-session state never
        // leaks across ids
        let e = NativeEngine::new();
        let ds1 = GeneratorConfig::small_demo(24, 3).generate(21);
        let ds2 = GeneratorConfig::small_demo(30, 3).generate(22);
        let opts = SolveOptions { epochs: 7, ..Default::default() };

        let isolated = |ds: &crate::sparse::generate::Dataset, sid| {
            let mut b = InProcessBackend::new(&e, 3);
            let (m, n) = ds.matrix.shape();
            let plan = PartitionPlan::contiguous(m, n, 3).unwrap();
            b.register_matrix(sid, InitKind::Qr, &plan, &ds.matrix).unwrap();
            let mut accs = vec![Vec::new()];
            b.seed_rhs(sid, &plan, &[&ds.rhs], &mut accs).unwrap();
            let mut xs =
                drive_apc_epochs_multi(&mut b, sid, &mut accs, &opts).unwrap();
            let mut x = xs.pop().unwrap();
            x.truncate(n);
            x
        };
        let want1 = isolated(&ds1, 1);
        let want2 = isolated(&ds2, 2);

        let mut b = InProcessBackend::new(&e, 3);
        let plan1 = PartitionPlan::contiguous(
            ds1.matrix.rows(),
            ds1.matrix.cols(),
            3,
        )
        .unwrap();
        let plan2 = PartitionPlan::contiguous(
            ds2.matrix.rows(),
            ds2.matrix.cols(),
            3,
        )
        .unwrap();
        b.register_matrix(1, InitKind::Qr, &plan1, &ds1.matrix).unwrap();
        b.register_matrix(2, InitKind::Qr, &plan2, &ds2.matrix).unwrap();
        let mut accs1 = vec![Vec::new()];
        let mut accs2 = vec![Vec::new()];
        b.seed_rhs(1, &plan1, &[&ds1.rhs], &mut accs1).unwrap();
        b.seed_rhs(2, &plan2, &[&ds2.rhs], &mut accs2).unwrap();
        // interleave the two epoch loops round by round
        let j = 3usize;
        let mut xb1: Vec<Vec<f32>> =
            accs1.iter().map(|a| mean_from_acc(a, j)).collect();
        let mut xb2: Vec<Vec<f32>> =
            accs2.iter().map(|a| mean_from_acc(a, j)).collect();
        for _ in 0..opts.epochs {
            b.run_round_batch(1, opts.gamma, opts.eta, &mut xb1, &mut accs1)
                .unwrap();
            b.run_round_batch(2, opts.gamma, opts.eta, &mut xb2, &mut accs2)
                .unwrap();
        }
        let mut got1 = xb1.pop().unwrap();
        got1.truncate(ds1.matrix.cols());
        let mut got2 = xb2.pop().unwrap();
        got2.truncate(ds2.matrix.cols());
        assert_eq!(got1, want1);
        assert_eq!(got2, want2);
    }

    #[test]
    fn auto_step_matches_dense_column_norms() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(4);
        let dense = ds.matrix.to_dense();
        let mut colsq = vec![0.0f64; dense.cols()];
        for r in 0..dense.rows() {
            for (c, v) in dense.row(r).iter().enumerate() {
                colsq[c] += (*v as f64) * (*v as f64);
            }
        }
        let total: f64 = colsq.iter().sum();
        let want = (1.0 / total.max(1e-12)) as f32;
        assert_eq!(auto_dgd_step(&ds.matrix), want);
    }
}
