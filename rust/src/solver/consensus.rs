//! The shared consensus driver (Algorithm 1) and the two APC solvers.
//!
//! Both variants run the identical epoch loop (eqs. (5)-(7)); they differ
//! only in the worker initialization: QR + backward substitution for the
//! paper's decomposed variant, Gram inverse for classical APC.

use std::time::Instant;

use crate::error::{DapcError, Result};
use crate::linalg::norms;
use crate::metrics::ConvergenceTrace;
use crate::partition::{PartitionPlan, PartitionRegime};
use crate::sparse::CsrMatrix;

use super::engine::{ComputeEngine, InitKind, RoundWorkspace};
use super::report::{residual_norm, SolveOptions, SolveReport};
use super::Solver;

/// Which APC initialization a consensus solver uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApcVariant {
    /// This paper: QR + backward substitution (O(l n^2), no inversion).
    Decomposed,
    /// Classical APC: Gram matrix + O(n^3) Gauss-Jordan inverse.
    Classical,
}

/// The paper's solver (decomposed APC).
#[derive(Debug, Clone)]
pub struct DapcSolver {
    pub options: SolveOptions,
}

impl DapcSolver {
    pub fn new(options: SolveOptions) -> Self {
        Self { options }
    }
}

/// Classical APC baseline.
#[derive(Debug, Clone)]
pub struct ApcClassicalSolver {
    pub options: SolveOptions,
}

impl ApcClassicalSolver {
    pub fn new(options: SolveOptions) -> Self {
        Self { options }
    }
}

impl Solver for DapcSolver {
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport> {
        run_apc(engine, a, b, j, ApcVariant::Decomposed, &self.options)
    }

    fn name(&self) -> &'static str {
        "dapc-decomposed"
    }
}

impl Solver for ApcClassicalSolver {
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport> {
        run_apc(engine, a, b, j, ApcVariant::Classical, &self.options)
    }

    fn name(&self) -> &'static str {
        "apc-classical"
    }
}

/// Full Algorithm 1 on a single process: partition -> init -> consensus.
pub fn run_apc<E: ComputeEngine>(
    engine: &E,
    a: &CsrMatrix,
    b: &[f32],
    j: usize,
    variant: ApcVariant,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let (m, n) = a.shape();
    if b.len() != m {
        return Err(DapcError::Shape(format!(
            "rhs length {} != matrix rows {m}",
            b.len()
        )));
    }
    let plan = PartitionPlan::contiguous(m, n, j)?;
    let init_kind = match (variant, plan.regime) {
        (_, PartitionRegime::Fat) => InitKind::Fat,
        (ApcVariant::Decomposed, PartitionRegime::Tall) => InitKind::Qr,
        (ApcVariant::Classical, PartitionRegime::Tall) => InitKind::Classical,
    };

    // ---- init phase (Algorithm 1 steps 1-4) -----------------------------
    let t0 = Instant::now();
    // engines may pad to a bucket; all partitions must agree on n_target
    let max_rows = plan.blocks.iter().map(|b| b.len()).max().unwrap();
    let n_target = engine
        .init_bucket(init_kind, max_rows, n)?
        .map(|(_, np)| np)
        .unwrap_or(n);
    // blocks are densified on demand inside init_all: the sequential
    // engine holds one at a time (unchanged peak memory), the parallel
    // engine extracts + factorizes partitions concurrently
    let inits = engine.init_all(
        init_kind,
        j,
        &|i| plan.extract(a, b, i),
        n_target,
    )?;
    let mut xs: Vec<Vec<f32>> = inits.iter().map(|w| w.x0.clone()).collect();
    let ps: Vec<_> = inits.into_iter().map(|w| w.projector).collect();
    // eq. (5): xbar(0) = mean of initial estimates
    let mut xbar = mean_rows(&xs);
    let init_time = t0.elapsed();

    // ---- iterate phase (steps 5-8) --------------------------------------
    let t1 = Instant::now();
    let mut trace = opts.x_true.as_ref().map(|xt| {
        let mut tr = ConvergenceTrace::new(match variant {
            ApcVariant::Decomposed => "dapc-decomposed",
            ApcVariant::Classical => "apc-classical",
        });
        tr.push(0, norms::mse(&xbar[..xt.len().min(xbar.len())], xt));
        tr
    });

    let fused = opts.fused_loop && trace.is_none();
    let mut done_fused = false;
    if fused {
        if let Some((new_xs, new_xbar)) = engine
            .solve_loop(&xs, &xbar, &ps, opts.gamma, opts.eta, opts.epochs)?
        {
            xs = new_xs;
            xbar = new_xbar;
            done_fused = true;
        }
    }
    if !done_fused {
        // steady-state loop: double-buffered estimates + a warmed
        // workspace, so every epoch is allocation-free on engines that
        // implement `round_into` in place (native and parallel both do)
        let mut ws = RoundWorkspace::for_shape(j, xbar.len());
        let mut next_xs: Vec<Vec<f32>> =
            xs.iter().map(|x| vec![0.0f32; x.len()]).collect();
        let mut next_xbar = vec![0.0f32; xbar.len()];
        for t in 0..opts.epochs {
            engine.round_into(
                &xs,
                &xbar,
                &ps,
                opts.gamma,
                opts.eta,
                &mut ws,
                &mut next_xs,
                &mut next_xbar,
            )?;
            std::mem::swap(&mut xs, &mut next_xs);
            std::mem::swap(&mut xbar, &mut next_xbar);
            if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
                tr.push(t + 1, norms::mse(&xbar[..xt.len().min(xbar.len())], xt));
            }
        }
    }
    let iterate_time = t1.elapsed();

    // strip any bucket padding
    xbar.truncate(n);
    for x in &mut xs {
        x.truncate(n);
    }
    let residual = residual_norm(a, b, &xbar);

    Ok(SolveReport {
        xbar,
        x_parts: xs,
        trace,
        residual: Some(residual),
        init_time,
        iterate_time,
        algorithm: match variant {
            ApcVariant::Decomposed => "dapc-decomposed",
            ApcVariant::Classical => "apc-classical",
        },
        engine: engine.name(),
        epochs: opts.epochs,
    })
}

fn mean_rows(xs: &[Vec<f32>]) -> Vec<f32> {
    let j = xs.len() as f64;
    let n = xs[0].len();
    (0..n)
        .map(|i| (xs.iter().map(|x| x[i] as f64).sum::<f64>() / j) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::engine::NativeEngine;
    use crate::sparse::generate::GeneratorConfig;

    fn opts(epochs: usize, x_true: Option<Vec<f32>>) -> SolveOptions {
        SolveOptions { epochs, eta: 0.9, gamma: 0.9, x_true, ..Default::default() }
    }

    #[test]
    fn decomposed_converges_on_augmented_system() {
        let ds = GeneratorConfig::small_demo(32, 3).generate(1);
        let e = NativeEngine::new();
        let solver = DapcSolver::new(opts(40, Some(ds.x_true.clone())));
        let report = solver.solve(&e, &ds.matrix, &ds.rhs, 3).unwrap();
        let mse = report.final_mse(&ds.x_true);
        assert!(mse < 1e-6, "mse = {mse}");
        let tr = report.trace.as_ref().unwrap();
        assert_eq!(tr.points.len(), 41);
        assert!(tr.final_mse().unwrap() <= tr.initial_mse().unwrap());
    }

    #[test]
    fn classical_converges_and_matches_decomposed() {
        let ds = GeneratorConfig::small_demo(24, 2).generate(2);
        let e = NativeEngine::new();
        let d = DapcSolver::new(opts(30, None))
            .solve(&e, &ds.matrix, &ds.rhs, 2)
            .unwrap();
        let c = ApcClassicalSolver::new(opts(30, None))
            .solve(&e, &ds.matrix, &ds.rhs, 2)
            .unwrap();
        assert!(d.final_mse(&ds.x_true) < 1e-6);
        assert!(c.final_mse(&ds.x_true) < 1e-4);
        // both variants converge to (approximately) the same solution
        assert!(norms::mse(&d.xbar, &c.xbar) < 1e-5);
    }

    #[test]
    fn fat_regime_selected_automatically() {
        // J so large the blocks go fat: original-APC projector path
        let ds = GeneratorConfig::small_demo(16, 1).generate(3);
        // matrix is 32x16; J=4 gives l=8 < n=16 => fat
        let e = NativeEngine::new();
        let solver = DapcSolver::new(SolveOptions {
            epochs: 300,
            eta: 0.6,
            gamma: 0.9,
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        });
        let report = solver.solve(&e, &ds.matrix, &ds.rhs, 4).unwrap();
        // fat-regime consensus genuinely iterates; should approach x_true
        let tr = report.trace.unwrap();
        assert!(
            tr.final_mse().unwrap() < tr.initial_mse().unwrap() * 0.5,
            "fat consensus did not reduce MSE: {:?} -> {:?}",
            tr.initial_mse(),
            tr.final_mse()
        );
    }

    #[test]
    fn mismatched_rhs_rejected() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(4);
        let e = NativeEngine::new();
        let r = DapcSolver::new(opts(1, None)).solve(&e, &ds.matrix, &ds.rhs[..3], 1);
        assert!(r.is_err());
    }

    #[test]
    fn single_partition_is_direct_solve() {
        let ds = GeneratorConfig::small_demo(16, 1).generate(5);
        let e = NativeEngine::new();
        let report = DapcSolver::new(opts(1, None))
            .solve(&e, &ds.matrix, &ds.rhs, 1)
            .unwrap();
        // J=1: init already solves the (overdetermined, consistent) system
        assert!(report.final_mse(&ds.x_true) < 1e-6);
    }
}
