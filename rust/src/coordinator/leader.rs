//! Leader: drives Algorithm 1 over a set of worker transports.
//!
//! The leader owns only n-length vectors; all O(l n) / O(n^2) state stays
//! on the workers.  Sends are pipelined (all J requests go out before the
//! first reply is awaited) so workers compute concurrently.

use std::time::Instant;

use crate::error::{DapcError, Result};
use crate::linalg::norms;
use crate::metrics::ConvergenceTrace;
use crate::partition::{PartitionPlan, PartitionRegime};
use crate::solver::{
    residual_norm, ApcVariant, InitKind, SolveOptions, SolveReport,
};
use crate::sparse::CsrMatrix;

use super::message::Message;
use super::transport::Transport;

/// Leader over J connected workers.
pub struct Leader<T: Transport> {
    workers: Vec<T>,
}

impl<T: Transport> Leader<T> {
    pub fn new(workers: Vec<T>) -> Self {
        Self { workers }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Run the APC consensus algorithm distributed over the workers.
    pub fn solve_apc(
        &mut self,
        a: &CsrMatrix,
        b: &[f32],
        variant: ApcVariant,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        let j = self.workers.len();
        let (m, n) = a.shape();
        let plan = PartitionPlan::contiguous(m, n, j)?;
        let init_kind = match (variant, plan.regime) {
            (_, PartitionRegime::Fat) => InitKind::Fat,
            (ApcVariant::Decomposed, _) => InitKind::Qr,
            (ApcVariant::Classical, _) => InitKind::Classical,
        };

        // ---- init: scatter partitions, gather x_j(0) --------------------
        let t0 = Instant::now();
        for i in 0..j {
            let (sub, rhs) = plan.extract(a, b, i);
            self.workers[i].send(&Message::InitPartition {
                worker_id: i as u32,
                kind: init_kind.into(),
                a: sub,
                b: rhs,
                n_target: n as u32,
            })?;
        }
        let mut xs: Vec<Vec<f32>> = vec![Vec::new(); j];
        for i in 0..j {
            match self.workers[i].recv()? {
                Message::InitDone { worker_id, x0 } => {
                    xs[worker_id as usize] = x0;
                }
                Message::WorkerError { worker_id, message } => {
                    return Err(DapcError::Coordinator(format!(
                        "worker {worker_id} init failed: {message}"
                    )))
                }
                other => {
                    return Err(DapcError::Coordinator(format!(
                        "unexpected reply {other:?}"
                    )))
                }
            }
        }
        let mut xbar = mean_rows(&xs);
        let init_time = t0.elapsed();

        // ---- consensus epochs -------------------------------------------
        let mut trace = opts.x_true.as_ref().map(|xt| {
            let mut tr = ConvergenceTrace::new("distributed-apc");
            tr.push(0, norms::mse(&xbar, xt));
            tr
        });
        let t1 = Instant::now();
        for epoch in 0..opts.epochs {
            for w in self.workers.iter_mut() {
                w.send(&Message::RunUpdate {
                    epoch: epoch as u32,
                    gamma: opts.gamma,
                    xbar: xbar.clone(),
                })?;
            }
            for i in 0..j {
                match self.workers[i].recv()? {
                    Message::UpdateDone { worker_id, x } => {
                        xs[worker_id as usize] = x;
                    }
                    Message::WorkerError { worker_id, message } => {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} update failed: {message}"
                        )))
                    }
                    other => {
                        return Err(DapcError::Coordinator(format!(
                            "unexpected reply {other:?}"
                        )))
                    }
                }
            }
            // eq. (7)
            let mean = mean_rows(&xs);
            for i in 0..n {
                xbar[i] = opts.eta * mean[i] + (1.0 - opts.eta) * xbar[i];
            }
            if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
                tr.push(epoch + 1, norms::mse(&xbar, xt));
            }
        }
        let iterate_time = t1.elapsed();
        let residual = residual_norm(a, b, &xbar);

        Ok(SolveReport {
            xbar,
            x_parts: xs,
            trace,
            residual: Some(residual),
            init_time,
            iterate_time,
            algorithm: match variant {
                ApcVariant::Decomposed => "dapc-decomposed",
                ApcVariant::Classical => "apc-classical",
            },
            engine: "distributed",
            epochs: opts.epochs,
        })
    }

    /// Distributed gradient descent over the same workers.
    pub fn solve_dgd(
        &mut self,
        a: &CsrMatrix,
        b: &[f32],
        alpha: f32,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        let j = self.workers.len();
        let (m, n) = a.shape();
        let plan = PartitionPlan::contiguous(m, n, j)?;

        let t0 = Instant::now();
        for i in 0..j {
            let (sub, rhs) = plan.extract(a, b, i);
            self.workers[i].send(&Message::InitPartition {
                worker_id: i as u32,
                kind: InitKind::Qr.into(), // init result unused for DGD
                a: sub,
                b: rhs,
                n_target: n as u32,
            })?;
        }
        for i in 0..j {
            let _ = self.workers[i].recv()?;
        }
        let init_time = t0.elapsed();

        let mut x = vec![0.0f32; n];
        let mut trace = opts.x_true.as_ref().map(|xt| {
            let mut tr = ConvergenceTrace::new("distributed-dgd");
            tr.push(0, norms::mse(&x, xt));
            tr
        });
        let t1 = Instant::now();
        for epoch in 0..opts.epochs {
            for w in self.workers.iter_mut() {
                w.send(&Message::RunGrad { epoch: epoch as u32, x: x.clone() })?;
            }
            let mut total = vec![0.0f64; n];
            for i in 0..j {
                match self.workers[i].recv()? {
                    Message::GradDone { grad, .. } => {
                        for (t, g) in total.iter_mut().zip(&grad) {
                            *t += *g as f64;
                        }
                    }
                    Message::WorkerError { worker_id, message } => {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} grad failed: {message}"
                        )))
                    }
                    other => {
                        return Err(DapcError::Coordinator(format!(
                            "unexpected reply {other:?}"
                        )))
                    }
                }
            }
            for (xi, g) in x.iter_mut().zip(&total) {
                *xi -= alpha * (*g as f32);
            }
            if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
                tr.push(epoch + 1, norms::mse(&x, xt));
            }
        }
        let iterate_time = t1.elapsed();
        let residual = residual_norm(a, b, &x);

        Ok(SolveReport {
            xbar: x.clone(),
            x_parts: vec![x],
            trace,
            residual: Some(residual),
            init_time,
            iterate_time,
            algorithm: "dgd",
            engine: "distributed",
            epochs: opts.epochs,
        })
    }

    /// Send shutdown to all workers (best-effort).
    pub fn shutdown(&mut self) {
        for w in self.workers.iter_mut() {
            let _ = w.send(&Message::Shutdown);
        }
    }
}

fn mean_rows(xs: &[Vec<f32>]) -> Vec<f32> {
    let j = xs.len() as f64;
    let n = xs[0].len();
    (0..n)
        .map(|i| (xs.iter().map(|x| x[i] as f64).sum::<f64>() / j) as f32)
        .collect()
}
