"""Layer-2 JAX graphs for Algorithm 1 (build-time only).

Each function here is a jit-able graph that ``aot.py`` lowers to HLO text
for the rust runtime.  They compose the Layer-1 Pallas kernels
(``kernels.consensus``) with the pure-HLO linalg substrate
(``kernels.linalg``); nothing in this module may touch a LAPACK-backed
jnp.linalg routine (see kernels/linalg.py docstring for why).

Graph inventory (names match artifact manifest entries):

  init_qr        (A_j, b_j)               -> (x0_j, P_j)   paper §2, eqs (1)-(4)
  init_classical (A_j, b_j)               -> (x0_j, P_j)   classical APC baseline
  init_fat       (A_j, b_j)               -> (x0_j, P_j)   original-APC fat regime
  update         (x_j, xbar, P_j, gamma)  -> x_j'          eq. (6), one worker
  average        (X, xbar, eta)           -> xbar'         eq. (7), leader
  round          (X, xbar, P, gamma, eta) -> (X', xbar')   fused epoch, all j
  solve_loop     (X, xbar, P, gamma, eta, T) -> (X', xbar') T epochs, one call
  dgd_grad       (A_j, x, b_j)            -> g_j           DGD baseline worker
  mse            (x, x_true)              -> scalar        Fig. 2 metric
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import consensus, linalg

__all__ = [
    "init_qr",
    "init_classical",
    "init_fat",
    "update",
    "average",
    "consensus_round",
    "solve_loop",
    "dgd_grad",
    "mse",
]


# ---------------------------------------------------------------------------
# Worker initialization (Algorithm 1, steps 2-3)
# ---------------------------------------------------------------------------

def init_qr(a: jnp.ndarray, b: jnp.ndarray):
    """Decomposed (this paper's) worker init for a tall block A_j (l, n).

    QR-factorizes A_j = Q1 R (eq. (1)), solves R x0 = Q1^T b by backward
    substitution (eqs. (2)-(3)) and forms the remapped projector
    P = I_n - Q1^T Q1 (eq. (4)).  Cost: O(l n^2) QR + O(n^2) backsub —
    no matrix inversion anywhere.
    """
    n = a.shape[1]
    q1, r = linalg.householder_qr(a)
    c = q1.T @ b
    x0 = linalg.back_substitution(r, c)
    p = jnp.eye(n, dtype=a.dtype) - q1.T @ q1
    return x0, p


def init_classical(a: jnp.ndarray, b: jnp.ndarray):
    """Classical APC worker init: Gram matrix + O(n^3) Gauss-Jordan inverse.

    x0 = (A^T A)^{-1} A^T b ;  P = I - (A^T A)^{-1} (A^T A), evaluated
    numerically — this is the inversion cost the paper's decomposition
    removes (Table 1's 'Classical APC' column).

    Internals run in f64 (requires the x64 flag aot.py sets): the paper's
    NumPy baseline is double precision, and the normal equations square
    kappa(A) — in f32 the numeric projector noise can exceed 1 and the
    consensus iteration diverges (DESIGN.md §1).
    """
    n = a.shape[1]
    a64 = a.astype(jnp.float64)
    b64 = b.astype(jnp.float64)
    g = a64.T @ a64
    ginv = linalg.gauss_jordan_inverse(g)
    x0 = ginv @ (a64.T @ b64)
    p = jnp.eye(n, dtype=jnp.float64) - ginv @ g
    return x0.astype(a.dtype), p.astype(a.dtype)


def init_fat(a: jnp.ndarray, b: jnp.ndarray):
    """Original-APC fat regime (l < n, Azizan-Ruhi et al. [7]) via QR.

    QR of A^T (n, l): A^T = Q R  =>  min-norm solution x0 = Q R^{-T} b
    (forward substitution on R^T), genuine nullspace projector
    P = I_n - Q Q^T.
    """
    n = a.shape[1]
    q, r = linalg.householder_qr(a.T)
    c = linalg.forward_substitution(r.T, b)
    x0 = q @ c
    p = jnp.eye(n, dtype=a.dtype) - q @ q.T
    return x0, p


# ---------------------------------------------------------------------------
# Consensus epochs (Algorithm 1, steps 5-8)
# ---------------------------------------------------------------------------

def update(x_j: jnp.ndarray, xbar: jnp.ndarray, p_j: jnp.ndarray, gamma):
    """Eq. (6) for a single worker (distributed mode artifact)."""
    xn = consensus.consensus_update(x_j[None, :], xbar, p_j[None, :, :], gamma)
    return xn[0]


def average(x: jnp.ndarray, xbar: jnp.ndarray, eta):
    """Eq. (7) on the leader: eta-mix of worker solutions."""
    return consensus.eta_average(x, xbar, eta)


def consensus_round(x, xbar, p, gamma, eta):
    """One fused epoch over all J partitions (single-process hot path)."""
    xn = consensus.consensus_update(x, xbar, p, gamma)
    return xn, consensus.eta_average(xn, xbar, eta)


def solve_loop(x, xbar, p, gamma, eta, epochs):
    """T consensus epochs in one executable (T is a runtime i32 scalar).

    The whole iterate phase of Algorithm 1 becomes a single PJRT call —
    the fusion ablation (benches/ablation_fusion.rs) compares this against
    per-epoch round calls and per-op updates.
    """

    def body(_, state):
        xs, xb = state
        return consensus_round(xs, xb, p, gamma, eta)

    return lax.fori_loop(0, epochs, body, (x, xbar))


# ---------------------------------------------------------------------------
# Baselines and metrics
# ---------------------------------------------------------------------------

def dgd_grad(a: jnp.ndarray, x: jnp.ndarray, b: jnp.ndarray):
    """DGD worker gradient g_j = A_j^T (A_j x - b_j) (Fig. 2 baseline)."""
    return a.T @ (a @ x - b)


def mse(x: jnp.ndarray, x_true: jnp.ndarray):
    """Mean squared error between estimate and reference (Fig. 2 y-axis)."""
    d = x - x_true
    return jnp.mean(d * d)
