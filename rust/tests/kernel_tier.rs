//! The two-tier kernel determinism contract, end to end.
//!
//! Tier-0 (`KernelTier::Deterministic`, the default) keeps every f32
//! gemm bitwise-identical across scalar/AVX2 dispatch and thread counts.
//! Tier-1 (`KernelTier::Fast`, opt-in via `DAPC_KERNEL_TIER=fast` or
//! [`SolveOptions::kernel_tier`]) fuses the f32 multiply-add in the
//! microkernel: faster and *more* accurate per depth step, but no longer
//! bit-identical to tier-0.  What tier-1 still promises — and this suite
//! enforces — is
//!
//! * reproducibility: the same inputs on the same backend+tier give the
//!   same bits, run after run and at any thread count (chunk-stable
//!   packing keeps pooled == serial bitwise *within* a tier), and
//! * accuracy: the tier gap is bounded by the unfused kernel's own
//!   rounding budget (`~k·eps` relative to the accumulated magnitude),
//!   so every tolerance-based suite in this repo passes on either tier.

use dapc::linalg::blas::{self, GemmPath};
use dapc::linalg::simd::{self, KernelTier};
use dapc::linalg::{norms, Matrix};
use dapc::rng::seeded;
use dapc::solver::{DapcSolver, NativeEngine, ParallelEngine, SolveOptions, Solver};
use dapc::sparse::generate::GeneratorConfig;

fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut g = seeded(seed);
    Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
}

fn gemm_with_tier(tier: KernelTier, a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    blas::gemm_into_on(simd::active(), tier, GemmPath::Packed, a, b, &mut c);
    c
}

#[test]
fn fast_tier_is_opt_in_and_engines_inherit_the_process_default() {
    // the process default follows DAPC_KERNEL_TIER exactly: unset (or
    // anything but "fast") means tier-0 — the fast tier never turns
    // itself on
    let env_fast = dapc::config::envvars::fast_tier();
    let expect = if env_fast {
        KernelTier::Fast
    } else {
        KernelTier::Deterministic
    };
    assert_eq!(simd::active_tier(), expect);
    assert_eq!(NativeEngine::new().tier(), expect);
    assert_eq!(NativeEngine::default().tier(), expect);
    assert_eq!(ParallelEngine::new(2).tier(), expect);
    // explicit construction overrides the env in either direction
    assert_eq!(NativeEngine::with_tier(KernelTier::Fast).tier(), KernelTier::Fast);
    let pinned = NativeEngine::with_tier(KernelTier::Deterministic);
    assert_eq!(pinned.tier(), KernelTier::Deterministic);
    assert_eq!(ParallelEngine::with_tier(3, KernelTier::Fast).tier(), KernelTier::Fast);
}

#[test]
fn tier1_gemm_stays_within_the_forward_error_bound() {
    // |tier1 - tier0| per element is bounded by 2·k·eps·Σ|a_ip||b_pj|:
    // both kernels are dot products with ≤ 2k roundings, fusing only
    // removes some of them.  The bound is checked against an exact-ish
    // f64 accumulation of |a||b|, not against the outputs themselves.
    for &(m, k, n) in &[(13usize, 37usize, 19usize), (37, 130, 29), (64, 256, 24)] {
        let a = randm(m, k, 300 + k as u64);
        let b = randm(k, n, 400 + k as u64);
        let c0 = gemm_with_tier(KernelTier::Deterministic, &a, &b);
        let c1 = gemm_with_tier(KernelTier::Fast, &a, &b);
        for i in 0..m {
            for j in 0..n {
                let mut mag = 0.0f64;
                for p in 0..k {
                    mag += (a[(i, p)] as f64 * b[(p, j)] as f64).abs();
                }
                let bound = 2.0 * k as f64 * f32::EPSILON as f64 * mag.max(1.0);
                let diff = (c1[(i, j)] as f64 - c0[(i, j)] as f64).abs();
                assert!(
                    diff <= bound,
                    "({m},{k},{n}) at ({i},{j}): |{} - {}| = {diff:e} > {bound:e}",
                    c1[(i, j)],
                    c0[(i, j)]
                );
            }
        }
    }
}

#[test]
fn tier1_gemm_is_bitwise_reproducible_within_the_backend() {
    let a = randm(33, 129, 500);
    let b = randm(129, 21, 501);
    let first = gemm_with_tier(KernelTier::Fast, &a, &b);
    for run in 0..3 {
        let again = gemm_with_tier(KernelTier::Fast, &a, &b);
        for i in 0..first.rows() {
            for j in 0..first.cols() {
                assert_eq!(
                    first[(i, j)].to_bits(),
                    again[(i, j)].to_bits(),
                    "tier-1 rerun {run} drifted at ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn tier1_pooled_solve_is_bitwise_identical_to_tier1_serial() {
    // the chunk-stable packing contract is tier-independent: pooled ==
    // serial must hold bitwise *within* tier-1 too, at any thread count
    let ds = GeneratorConfig::small_demo(40, 3).generate(21);
    let opts = SolveOptions { epochs: 20, ..Default::default() };
    let serial = DapcSolver::new(opts.clone())
        .solve(&NativeEngine::with_tier(KernelTier::Fast), &ds.matrix, &ds.rhs, 3)
        .unwrap();
    for threads in [2usize, 4, 7] {
        let engine = ParallelEngine::with_tier(threads, KernelTier::Fast);
        let pooled = DapcSolver::new(opts.clone())
            .solve(&engine, &ds.matrix, &ds.rhs, 3)
            .unwrap();
        assert_eq!(serial.xbar, pooled.xbar, "tier-1 diverged at {threads} threads");
    }
    // and the fast tier still solves the system
    assert!(serial.final_mse(&ds.x_true) < 1e-6);
}

#[test]
fn cross_tier_solves_agree_to_solver_tolerance() {
    // tier-1 perturbs the QR factors at the k·eps level; after the
    // consensus iteration both tiers converge to the same solution well
    // inside the accuracy the solver itself claims
    let ds = GeneratorConfig::small_demo(48, 4).generate(33);
    let opts = SolveOptions { epochs: 30, ..Default::default() };
    let t0 = DapcSolver::new(opts.clone())
        .solve(&NativeEngine::with_tier(KernelTier::Deterministic), &ds.matrix, &ds.rhs, 4)
        .unwrap();
    let t1 = DapcSolver::new(opts)
        .solve(&NativeEngine::with_tier(KernelTier::Fast), &ds.matrix, &ds.rhs, 4)
        .unwrap();
    let gap = norms::mse(&t0.xbar, &t1.xbar);
    assert!(gap < 1e-8, "cross-tier solve gap {gap:e}");
    assert!(t0.final_mse(&ds.x_true) < 1e-6);
    assert!(t1.final_mse(&ds.x_true) < 1e-6);
}
