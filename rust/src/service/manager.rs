//! [`SessionManager`]: the multi-tenant owner of MANY warm sessions
//! over ONE [`SessionBackend`], with a configurable resident-memory cap
//! enforced by LRU eviction.
//!
//! # Lifecycle
//!
//! ```text
//!   register(a, config) ──► live (factorization resident backend-side)
//!        ▲                     │ cap pressure (LRU victim)
//!        │ next solve          ▼
//!        └──────────────── evicted (matrix + config retained manager-
//!                            side; backend state dropped)
//!   unregister(sid)     ──► gone (all state released, id invalid)
//! ```
//!
//! Eviction is **transparent**: the manager keeps each session's CSR
//! matrix and [`SessionConfig`], so the next solve against an evicted
//! id silently re-registers (re-factorizes) and then serves — bit-for-
//! bit identical to the pre-eviction solves, because registration is
//! deterministic.  What eviction costs is time, never numerics.
//!
//! # Accounting
//!
//! `resident_bytes()` tracks the projected backend-resident footprint
//! ([`crate::solver::resident_partition_bytes`]) of every LIVE session;
//! it decrements on eviction and unregister (the v5 accounting bugfix —
//! the old per-session stats never gave bytes back).  The same number
//! is mirrored to the `service.resident_bytes` gauge, with one
//! `service.s{id}.resident_bytes` gauge per session; the metrics
//! validator cross-checks that the per-session gauges sum to the total,
//! so stale accounting fails `dapc metrics-validate`.  Cap-forced
//! evictions count into `service.evictions`.
//!
//! The cap is enforced BEFORE the incoming factorization is built, so
//! the total never exceeds it even transiently — with one documented
//! exception: a single session whose own footprint exceeds the cap is
//! admitted after evicting everything else (rejecting it would make the
//! service unusable under a misconfigured cap); the invariant is
//! `resident_bytes() <= max(cap, largest single session)`.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::error::{DapcError, Result};
use crate::obs::{self, Counter, Gauge};
use crate::solver::{SessionBackend, SessionId, SolveReport};
use crate::sparse::CsrMatrix;

use super::session::{next_session_id, projected_resident_bytes, SessionCore};
use super::{ServiceStats, SessionConfig};

/// One tenant's entry: live core or evicted remains.
struct Managed {
    /// Live serving state; `None` while evicted (the backend dropped
    /// the factorization — `a` + `config` rebuild it on next use).
    core: Option<SessionCore>,
    a: Arc<CsrMatrix>,
    config: SessionConfig,
    /// Backend-resident bytes while live (0 while evicted).
    resident_bytes: u64,
    /// Counters carried across evictions (authoritative while evicted;
    /// merged into the fresh core on re-registration).
    saved_stats: ServiceStats,
    /// LRU tick of the last register/solve touching this session.
    last_used: u64,
}

/// Owns many warm sessions over one backend; see the module docs for
/// the lifecycle and the eviction/accounting contract.
pub struct SessionManager<'b, B: SessionBackend + ?Sized> {
    backend: &'b mut B,
    sessions: BTreeMap<SessionId, Managed>,
    /// Resident-memory cap over all live sessions (`None` = uncapped).
    max_resident_bytes: Option<u64>,
    /// Sum of live sessions' `resident_bytes` (mirrored to the
    /// `service.resident_bytes` gauge).
    resident_total: u64,
    /// LRU clock.
    clock: u64,
    /// Cap-forced evictions (local count; tests read it with metrics
    /// off, the counter feeds `service.evictions`).
    evicted_count: u64,
    evictions: Arc<Counter>,
    resident_gauge: Arc<Gauge>,
}

impl<'b, B: SessionBackend + ?Sized> SessionManager<'b, B> {
    /// Uncapped manager: sessions stay resident until unregistered.
    pub fn new(backend: &'b mut B) -> Self {
        Self::build(backend, None)
    }

    /// Manager with a resident-memory cap in bytes (LRU eviction).
    pub fn with_memory_cap(backend: &'b mut B, max_resident_bytes: u64) -> Self {
        Self::build(backend, Some(max_resident_bytes))
    }

    fn build(backend: &'b mut B, cap: Option<u64>) -> Self {
        Self {
            backend,
            sessions: BTreeMap::new(),
            max_resident_bytes: cap,
            resident_total: 0,
            clock: 0,
            evicted_count: 0,
            evictions: obs::counter("service.evictions"),
            resident_gauge: obs::gauge("service.resident_bytes"),
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn session_gauge(sid: SessionId) -> Arc<Gauge> {
        obs::gauge(&format!("service.s{sid}.resident_bytes"))
    }

    /// Register `a` under a fresh session id and return the id.  May
    /// evict LRU sessions first to make room under the cap.
    pub fn register(
        &mut self,
        a: CsrMatrix,
        config: SessionConfig,
    ) -> Result<SessionId> {
        let sid = next_session_id();
        let a = Arc::new(a);
        let incoming = projected_resident_bytes(
            &a,
            &config,
            self.backend.partitions(),
        )?;
        self.make_room(incoming, sid)?;
        let core = SessionCore::register(
            &mut *self.backend,
            sid,
            a.clone(),
            config.clone(),
        )?;
        let resident = core.resident_bytes();
        self.resident_total += resident;
        Self::session_gauge(sid).set(resident as f64);
        self.resident_gauge.set(self.resident_total as f64);
        let tick = self.tick();
        self.sessions.insert(
            sid,
            Managed {
                core: Some(core),
                a,
                config,
                resident_bytes: resident,
                saved_stats: ServiceStats::default(),
                last_used: tick,
            },
        );
        Ok(sid)
    }

    /// Evict LRU live sessions (skipping `incoming_sid`) until
    /// `incoming` more bytes fit under the cap.  Stops early when no
    /// other live session remains — a single oversized session is
    /// admitted rather than wedging the service.
    fn make_room(&mut self, incoming: u64, incoming_sid: SessionId) -> Result<()> {
        let Some(cap) = self.max_resident_bytes else {
            return Ok(());
        };
        while self.resident_total.saturating_add(incoming) > cap {
            let victim = self
                .sessions
                .iter()
                .filter(|(id, m)| m.core.is_some() && **id != incoming_sid)
                .min_by_key(|(_, m)| m.last_used)
                .map(|(id, _)| *id);
            match victim {
                Some(v) => self.evict(v)?,
                None => break,
            }
        }
        Ok(())
    }

    /// Drop `sid`'s backend-resident state, retaining the matrix and
    /// config for transparent re-registration.
    fn evict(&mut self, sid: SessionId) -> Result<()> {
        let m = self
            .sessions
            .get_mut(&sid)
            .expect("evict victim chosen from the session map");
        let core = m.core.take().expect("evict victim is live");
        m.saved_stats = core.stats().clone();
        self.backend.unregister_session(sid)?;
        self.resident_total -= m.resident_bytes;
        m.resident_bytes = 0;
        Self::session_gauge(sid).set(0.0);
        self.resident_gauge.set(self.resident_total as f64);
        self.evicted_count += 1;
        self.evictions.inc();
        Ok(())
    }

    /// Re-register an evicted session (no-op when live).  The rebuilt
    /// factorization is deterministic, so post-revival solves are
    /// bit-identical to pre-eviction ones.
    fn revive(&mut self, sid: SessionId) -> Result<()> {
        let m = self.session_entry(sid)?;
        if m.core.is_some() {
            return Ok(());
        }
        let (a, config, saved) =
            (m.a.clone(), m.config.clone(), m.saved_stats.clone());
        let incoming = projected_resident_bytes(
            &a,
            &config,
            self.backend.partitions(),
        )?;
        self.make_room(incoming, sid)?;
        let mut core =
            SessionCore::register(&mut *self.backend, sid, a, config)?;
        // carry the pre-eviction serving counters; registration cost
        // accumulates (the session has now paid for two factorizations)
        let fresh = core.stats_mut();
        fresh.register_time += saved.register_time;
        fresh.solve_calls = saved.solve_calls;
        fresh.rhs_served = saved.rhs_served;
        fresh.max_batch = saved.max_batch;
        fresh.solve_time = saved.solve_time;
        let resident = core.resident_bytes();
        self.resident_total += resident;
        Self::session_gauge(sid).set(resident as f64);
        self.resident_gauge.set(self.resident_total as f64);
        let m = self
            .sessions
            .get_mut(&sid)
            .expect("session checked by session_entry above");
        m.core = Some(core);
        m.resident_bytes = resident;
        Ok(())
    }

    fn session_entry(&mut self, sid: SessionId) -> Result<&mut Managed> {
        self.sessions.get_mut(&sid).ok_or_else(|| {
            DapcError::Coordinator(format!(
                "unknown session {sid}: never registered with this manager \
                 (or already unregistered)"
            ))
        })
    }

    /// Serve one right-hand side through session `sid`, transparently
    /// re-registering it if evicted.
    pub fn solve(&mut self, sid: SessionId, b: &[f32]) -> Result<SolveReport> {
        let mut reports = self.solve_batch(sid, &[b])?;
        Ok(reports.pop().expect("one report per rhs"))
    }

    /// Serve a column-blocked batch through session `sid` (see
    /// [`super::SolverSession::solve_batch`] for the batching
    /// contract), transparently re-registering it if evicted.
    pub fn solve_batch<S: AsRef<[f32]>>(
        &mut self,
        sid: SessionId,
        bs: &[S],
    ) -> Result<Vec<SolveReport>> {
        self.revive(sid)?;
        let tick = self.tick();
        let m = self
            .sessions
            .get_mut(&sid)
            .expect("session revived above");
        m.last_used = tick;
        let core = m.core.as_mut().expect("session revived above");
        let refs: Vec<&[f32]> = bs.iter().map(|b| b.as_ref()).collect();
        core.solve_batch_refs(&mut *self.backend, &refs)
    }

    /// Release ALL of `sid`'s state — backend-resident factorization
    /// and the manager-side matrix/config — invalidating the id.
    pub fn unregister(&mut self, sid: SessionId) -> Result<()> {
        let m = self.session_entry(sid)?;
        let was_live = m.core.is_some();
        let bytes = m.resident_bytes;
        if was_live {
            self.backend.unregister_session(sid)?;
            self.resident_total -= bytes;
            Self::session_gauge(sid).set(0.0);
            self.resident_gauge.set(self.resident_total as f64);
        }
        self.sessions.remove(&sid);
        Ok(())
    }

    /// Whether `sid` is registered with this manager (live OR evicted).
    pub fn contains(&self, sid: SessionId) -> bool {
        self.sessions.contains_key(&sid)
    }

    /// Whether `sid`'s factorization is currently backend-resident
    /// (false when evicted or unknown).
    pub fn is_resident(&self, sid: SessionId) -> bool {
        self.sessions.get(&sid).is_some_and(|m| m.core.is_some())
    }

    /// Total backend-resident bytes across live sessions.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_total
    }

    /// The configured cap, if any.
    pub fn max_resident_bytes(&self) -> Option<u64> {
        self.max_resident_bytes
    }

    /// Cap-forced evictions so far.
    pub fn evictions(&self) -> u64 {
        self.evicted_count
    }

    /// Registered session ids (live and evicted), ascending.
    pub fn session_ids(&self) -> Vec<SessionId> {
        self.sessions.keys().copied().collect()
    }

    /// Registered-session count (live and evicted).
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Amortization counters for `sid` (survives eviction; `None` for
    /// unknown ids).
    pub fn stats(&self, sid: SessionId) -> Option<ServiceStats> {
        self.sessions.get(&sid).map(|m| match &m.core {
            Some(core) => core.stats().clone(),
            None => m.saved_stats.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::SolverSession;
    use crate::solver::{ApcVariant, InProcessBackend, NativeEngine};
    use crate::sparse::generate::GeneratorConfig;

    fn cfg(epochs: usize) -> SessionConfig {
        SessionConfig::apc(ApcVariant::Decomposed).epochs(epochs)
    }

    #[test]
    fn interleaved_sessions_match_isolated_sessions() {
        let ds1 = GeneratorConfig::small_demo(16, 2).generate(51);
        let ds2 = GeneratorConfig::small_demo(20, 2).generate(52);
        let e = NativeEngine::new();

        // isolated references, one backend each
        let mut ib1 = InProcessBackend::new(&e, 2);
        let mut ref1 =
            SolverSession::register(&mut ib1, ds1.matrix.clone(), cfg(12))
                .unwrap();
        let r1 = ref1.solve(&ds1.rhs).unwrap();
        let mut ib2 = InProcessBackend::new(&e, 2);
        let mut ref2 =
            SolverSession::register(&mut ib2, ds2.matrix.clone(), cfg(12))
                .unwrap();
        let r2 = ref2.solve(&ds2.rhs).unwrap();

        // one manager, one backend, interleaved serving
        let mut backend = InProcessBackend::new(&e, 2);
        let mut mgr = SessionManager::new(&mut backend);
        let s1 = mgr.register(ds1.matrix.clone(), cfg(12)).unwrap();
        let s2 = mgr.register(ds2.matrix.clone(), cfg(12)).unwrap();
        for _ in 0..2 {
            assert_eq!(mgr.solve(s1, &ds1.rhs).unwrap().xbar, r1.xbar);
            assert_eq!(mgr.solve(s2, &ds2.rhs).unwrap().xbar, r2.xbar);
        }
        assert_eq!(mgr.len(), 2);
        assert_eq!(mgr.evictions(), 0);
        assert_eq!(mgr.stats(s1).unwrap().rhs_served, 2);
    }

    #[test]
    fn cap_forces_lru_eviction_and_revival_is_bitwise() {
        let ds = GeneratorConfig::small_demo(18, 3).generate(53);
        let e = NativeEngine::new();

        // learn one session's footprint with an uncapped manager
        let per_session = {
            let mut b = InProcessBackend::new(&e, 3);
            let mut m = SessionManager::new(&mut b);
            let sid = m.register(ds.matrix.clone(), cfg(8)).unwrap();
            m.stats(sid).unwrap().resident_bytes_total()
        };
        assert!(per_session > 0);

        // room for exactly two
        let cap = 2 * per_session;
        let mut backend = InProcessBackend::new(&e, 3);
        let mut mgr = SessionManager::with_memory_cap(&mut backend, cap);
        let s1 = mgr.register(ds.matrix.clone(), cfg(8)).unwrap();
        let s2 = mgr.register(ds.matrix.clone(), cfg(8)).unwrap();
        let first = mgr.solve(s1, &ds.rhs).unwrap();
        assert!(mgr.resident_bytes() <= cap);
        assert_eq!(mgr.evictions(), 0);

        // third tenant: s2 is LRU (s1 was just used) and gets evicted
        let s3 = mgr.register(ds.matrix.clone(), cfg(8)).unwrap();
        assert!(mgr.resident_bytes() <= cap);
        assert_eq!(mgr.evictions(), 1);
        assert!(mgr.is_resident(s1) && mgr.is_resident(s3));
        assert!(!mgr.is_resident(s2));
        assert_eq!(mgr.len(), 3, "evicted sessions stay registered");

        // solving the evicted session transparently revives it (evicting
        // the new LRU, s1) and reproduces the original solve bit-for-bit
        let revived = mgr.solve(s2, &ds.rhs).unwrap();
        assert_eq!(revived.xbar, first.xbar);
        assert!(mgr.is_resident(s2));
        assert!(!mgr.is_resident(s1));
        assert!(mgr.resident_bytes() <= cap);
        assert_eq!(mgr.evictions(), 2);
        // counters survived the eviction round-trip
        assert_eq!(mgr.stats(s2).unwrap().rhs_served, 1);

        // and s1 revives too, still bitwise
        assert_eq!(mgr.solve(s1, &ds.rhs).unwrap().xbar, first.xbar);
    }

    #[test]
    fn oversized_session_admitted_after_evicting_everything() {
        let ds = GeneratorConfig::small_demo(18, 3).generate(54);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 3);
        // cap of 1 byte: every APC session is oversized
        let mut mgr = SessionManager::with_memory_cap(&mut backend, 1);
        let s1 = mgr.register(ds.matrix.clone(), cfg(6)).unwrap();
        let r1 = mgr.solve(s1, &ds.rhs).unwrap();
        // the second tenant evicts the first, then runs oversized itself
        let s2 = mgr.register(ds.matrix.clone(), cfg(6)).unwrap();
        assert!(!mgr.is_resident(s1));
        assert!(mgr.is_resident(s2));
        assert_eq!(mgr.evictions(), 1);
        // both still serve, bitwise identical
        assert_eq!(mgr.solve(s2, &ds.rhs).unwrap().xbar, r1.xbar);
        assert_eq!(mgr.solve(s1, &ds.rhs).unwrap().xbar, r1.xbar);
    }

    #[test]
    fn unregister_releases_bytes_and_invalidates_id() {
        let ds = GeneratorConfig::small_demo(14, 2).generate(55);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut mgr = SessionManager::new(&mut backend);
        let s1 = mgr.register(ds.matrix.clone(), cfg(4)).unwrap();
        let s2 = mgr.register(ds.matrix.clone(), cfg(4)).unwrap();
        let total = mgr.resident_bytes();
        assert!(total > 0);

        mgr.unregister(s1).unwrap();
        assert_eq!(mgr.len(), 1);
        assert!(mgr.resident_bytes() < total);
        let err = mgr.solve(s1, &ds.rhs).unwrap_err().to_string();
        assert!(err.contains("unknown session"), "{err}");
        assert!(mgr.unregister(s1).is_err(), "double unregister rejected");

        mgr.unregister(s2).unwrap();
        assert_eq!(mgr.resident_bytes(), 0);
        assert!(mgr.is_empty());
    }

    #[test]
    fn dgd_sessions_are_weightless() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(56);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut mgr = SessionManager::with_memory_cap(&mut backend, 1);
        let sid = mgr
            .register(ds.matrix.clone(), SessionConfig::dgd().epochs(10))
            .unwrap();
        assert_eq!(mgr.resident_bytes(), 0);
        assert_eq!(
            mgr.stats(sid).unwrap().resident_partition_bytes.len(),
            0
        );
        assert_eq!(mgr.solve(sid, &ds.rhs).unwrap().algorithm, "dgd");
        assert_eq!(mgr.evictions(), 0);
    }
}
