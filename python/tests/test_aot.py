"""AOT pipeline: every manifest entry lowers to parseable HLO text with the
declared I/O signature, and the emitted text avoids LAPACK custom-calls
(which the rust PJRT client cannot execute).
"""

import json
import os
import re
import tempfile

import pytest

from compile import aot, shapes


class TestShapes:
    def test_default_problems_valid(self):
        for pb in shapes.DEFAULT_PROBLEMS:
            assert pb.j >= 1 and pb.n >= 1 and pb.l >= 1

    def test_full_problems_match_table1(self):
        # all Table-1 rows have m = 4n and J = 2; padded to 128-multiples
        assert len(shapes.FULL_PROBLEMS) == 5
        for pb in shapes.FULL_PROBLEMS:
            assert pb.j == 2
            assert pb.l % 128 == 0 and pb.n % 128 == 0
            assert pb.tall

    def test_pad(self):
        assert shapes._pad(2327) == 2432
        assert shapes._pad(128) == 128
        assert shapes._pad(1) == 128


class TestGraphEntries:
    def test_entry_names_unique(self):
        entries = aot.graph_entries(full=False)
        names = [e["name"] for e in entries]
        assert len(names) == len(set(names))

    def test_covers_all_kinds(self):
        kinds = {e["params"]["kind"] for e in aot.graph_entries(full=False)}
        assert kinds == {
            "init_qr", "init_classical", "init_fat", "update",
            "average", "round", "solve", "dgd_grad", "mse",
        }


@pytest.mark.slow
class TestLowering:
    def test_small_entry_lowers_to_portable_hlo(self):
        entries = [
            e for e in aot.graph_entries(full=False)
            if e["name"] in ("update_n32", "round_j2_n32", "init_qr_l64_n32")
        ]
        assert len(entries) == 3
        with tempfile.TemporaryDirectory() as d:
            for e in entries:
                meta = aot.lower_entry(e, d)
                path = os.path.join(d, meta["file"])
                text = open(path).read()
                assert text.startswith("HloModule")
                # portability: no custom-call to LAPACK/Mosaic anywhere
                assert "custom-call" not in text, e["name"]
                # declared inputs match the lowered entry signature
                sig = re.search(r"entry_computation_layout=\{\(([^)]*)\)", text)
                assert sig is not None
                assert len(meta["inputs"]) == len(
                    [s for s in sig.group(1).split(", ") if s]
                )

    def test_manifest_roundtrip(self):
        entries = [
            e for e in aot.graph_entries(full=False)
            if e["name"] == "mse_n32"
        ]
        with tempfile.TemporaryDirectory() as d:
            metas = [aot.lower_entry(e, d) for e in entries]
            with open(os.path.join(d, "manifest.json"), "w") as f:
                json.dump(metas, f)
            back = json.load(open(os.path.join(d, "manifest.json")))
            assert back[0]["name"] == "mse_n32"
            assert back[0]["params"]["kind"] == "mse"
