//! Comment/string-aware line lexer backing the audit rules.
//!
//! The analyzer is deliberately not a Rust parser: the determinism
//! contracts it enforces are all expressible as *token presence* ("an
//! `unsafe` keyword", "a `HashMap` path", "a `.fold(` seeded with a
//! float literal") plus a little brace tracking for the wire rule.  What
//! a token matcher must not do is fire on words inside comments, doc
//! text, or string literals — so this lexer splits every source line
//! into channels first:
//!
//! * **code** — comments removed, string/char-literal *contents* blanked
//!   to spaces (delimiters kept, so column positions survive);
//! * **comment** — the text of `//…` and `/* … */` comments on the line
//!   (where `// SAFETY:` and `// audit:allow(...)` markers live);
//! * **strings** — the contents of string literals that start on or
//!   span the line (paired with the code channel by the `env-registry`
//!   rule to catch `env::var("DAPC_…")`).
//!
//! Handled: nested block comments, doc comments, raw strings
//! (`r#"…"#`, byte variants), escapes, char literals vs. lifetimes.
//! Multi-line literals and comments carry lexer state across lines.

/// One source line split into rule-facing channels.
#[derive(Debug, Clone)]
pub struct Line {
    /// Original text, untouched — finding excerpts come from here.
    pub raw: String,
    /// Code with comments stripped and literal contents blanked.
    pub code: String,
    /// Concatenated comment text on this line.
    pub comment: String,
    /// String-literal contents beginning on (or crossing) this line.
    pub strings: Vec<String>,
}

enum State {
    Code,
    /// Inside `/* … */`; the payload is the nesting depth (Rust block
    /// comments nest).
    Block(u32),
    /// Inside a plain `"…"` (or `b"…"`) string.
    Str,
    /// Inside a raw string; the payload is the `#` count.
    RawStr(u8),
}

/// Split `src` into per-line channels.  Never fails: unterminated
/// literals/comments simply run to end of input, which is the right
/// behaviour for a linter that must not crash on in-progress code.
pub fn lex(src: &str) -> Vec<Line> {
    let mut st = State::Code;
    let mut out = Vec::new();
    for raw_line in src.lines() {
        let chars: Vec<char> = raw_line.chars().collect();
        let mut code = String::new();
        let mut comment = String::new();
        let mut strings: Vec<String> = Vec::new();
        let mut cur = String::new();
        let mut i = 0usize;
        while i < chars.len() {
            match st {
                State::Code => {
                    let c = chars[i];
                    let next = chars.get(i + 1).copied();
                    if c == '/' && next == Some('/') {
                        // line comment (incl. /// and //! doc forms)
                        comment.extend(&chars[i..]);
                        i = chars.len();
                    } else if c == '/' && next == Some('*') {
                        st = State::Block(1);
                        i += 2;
                    } else if (c == 'r' || c == 'b')
                        && !prev_is_ident(&code)
                    {
                        if let Some((len, hashes)) =
                            raw_str_open(&chars, i)
                        {
                            code.extend(&chars[i..i + len]);
                            i += len;
                            st = State::RawStr(hashes);
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else if c == '"' {
                        code.push('"');
                        st = State::Str;
                        i += 1;
                    } else if c == '\'' {
                        if let Some(end) = char_literal_end(&chars, i) {
                            // blank the contents, keep the delimiters
                            code.push('\'');
                            for _ in i + 1..end {
                                code.push(' ');
                            }
                            code.push('\'');
                            i = end + 1;
                        } else {
                            // lifetime tick
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                State::Block(depth) => {
                    if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        st = if depth == 1 {
                            State::Code
                        } else {
                            State::Block(depth - 1)
                        };
                        i += 2;
                    } else if chars[i] == '/'
                        && chars.get(i + 1) == Some(&'*')
                    {
                        st = State::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(chars[i]);
                        i += 1;
                    }
                }
                State::Str => {
                    let c = chars[i];
                    if c == '\\' {
                        cur.push(c);
                        code.push(' ');
                        if let Some(&n) = chars.get(i + 1) {
                            cur.push(n);
                            code.push(' ');
                            i += 2;
                        } else {
                            i += 1;
                        }
                    } else if c == '"' {
                        strings.push(std::mem::take(&mut cur));
                        code.push('"');
                        st = State::Code;
                        i += 1;
                    } else {
                        cur.push(c);
                        code.push(' ');
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    if chars[i] == '"' && raw_str_closes(&chars, i, hashes)
                    {
                        strings.push(std::mem::take(&mut cur));
                        code.push('"');
                        for _ in 0..hashes {
                            code.push('#');
                        }
                        st = State::Code;
                        i += 1 + hashes as usize;
                    } else {
                        cur.push(chars[i]);
                        code.push(' ');
                        i += 1;
                    }
                }
            }
        }
        // a literal continuing onto the next line still exposes the part
        // seen so far (DAPC_* names never span lines, but be total)
        if !cur.is_empty() {
            strings.push(std::mem::take(&mut cur));
        }
        out.push(Line {
            raw: raw_line.to_string(),
            code,
            comment,
            strings,
        });
    }
    out
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().last().map(is_ident).unwrap_or(false)
}

/// At `chars[i]` (an `r` or `b`): is this `r"`, `br#"`, `r##"`, …?
/// Returns (chars up to and including the opening quote, hash count).
fn raw_str_open(chars: &[char], i: usize) -> Option<(usize, u8)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u8;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
        if hashes > 16 {
            return None;
        }
    }
    if chars.get(j) == Some(&'"') {
        Some((j - i + 1, hashes))
    } else {
        None
    }
}

/// At `chars[i] == '"'` inside a raw string: do `hashes` `#`s follow?
fn raw_str_closes(chars: &[char], i: usize, hashes: u8) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// At `chars[i] == '\''`: if this opens a char literal, return the index
/// of its closing quote; `None` means it is a lifetime tick.
fn char_literal_end(chars: &[char], i: usize) -> Option<usize> {
    let next = *chars.get(i + 1)?;
    if next == '\\' {
        // escaped char ('\n', '\'', '\u{1F600}'): closing quote comes
        // after the escape sequence — bounded scan keeps a stray
        // backslash from eating the rest of the line
        let mut j = i + 3;
        while j < chars.len() && j <= i + 12 {
            if chars[j] == '\'' {
                return Some(j);
            }
            j += 1;
        }
        None
    } else if next != '\'' && chars.get(i + 2) == Some(&'\'') {
        Some(i + 2)
    } else {
        None
    }
}

/// Word-boundary search for `token` in the code channel: the characters
/// around the match must not be identifier characters, so `unsafe` does
/// not fire inside `rule_unsafe_confined` or `UnsafeCell`.
pub fn has_token(code: &str, token: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(token) {
        let abs = start + pos;
        let before_ok = code[..abs]
            .chars()
            .last()
            .map(|c| !is_ident(c))
            .unwrap_or(true);
        let after_ok = code[abs + token.len()..]
            .chars()
            .next()
            .map(|c| !is_ident(c))
            .unwrap_or(true);
        if before_ok && after_ok {
            return true;
        }
        start = abs + token.len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_separated_from_code() {
        let lines = lex("let x = 1; // trailing note\n/* block */ let y = 2;");
        assert_eq!(lines[0].code.trim(), "let x = 1;");
        assert!(lines[0].comment.contains("trailing note"));
        assert_eq!(lines[1].code.trim(), "let y = 2;");
        assert!(lines[1].comment.contains("block"));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let lines = lex("/* a /* b */ still comment */ code();");
        assert_eq!(lines[0].code.trim(), "code();");
        assert!(lines[0].comment.contains("still comment"));
    }

    #[test]
    fn string_contents_are_blanked_but_captured() {
        let src = "call(\"token_inside\"); other();";
        let lines = lex(src);
        assert!(!lines[0].code.contains("token_inside"));
        assert!(lines[0].code.contains("call(\""));
        assert_eq!(lines[0].strings, vec!["token_inside".to_string()]);
    }

    #[test]
    fn raw_strings_and_escapes() {
        let src = "let a = r#\"raw \"quoted\" body\"#; let b = \"es\\\"c\";";
        let lines = lex(src);
        assert_eq!(lines[0].strings.len(), 2);
        assert_eq!(lines[0].strings[0], "raw \"quoted\" body");
        assert_eq!(lines[0].strings[1], "es\\\"c");
        assert!(lines[0].code.contains("let b ="));
    }

    #[test]
    fn multiline_block_comment_state_persists() {
        let lines = lex("before(); /* spans\nlines */ after();");
        assert_eq!(lines[0].code.trim(), "before();");
        assert_eq!(lines[1].code.trim(), "after();");
        assert!(lines[1].comment.contains("lines"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'env>(x: &'env str, c: char) { m(c, 'x', '\\n'); }";
        let lines = lex(src);
        // lifetimes survive in code; char-literal contents are blanked
        assert!(lines[0].code.contains("'env"));
        assert!(!lines[0].code.contains("'x'"));
        assert!(lines[0].code.contains("' '"));
    }

    #[test]
    fn token_matching_respects_word_boundaries() {
        assert!(has_token("unsafe { work() }", "unsafe"));
        assert!(!has_token("rule_unsafe_confined()", "unsafe"));
        assert!(!has_token("UnsafeCell::new(0)", "unsafe"));
        assert!(has_token("x.mul_add(y, z)", "mul_add"));
        assert!(!has_token("smul_adder(y)", "mul_add"));
    }
}
