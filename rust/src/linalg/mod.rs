//! Dense linear-algebra substrate (native Rust engine + test oracle).
//!
//! The paper's workers run QR factorizations, triangular solves and (for
//! the classical-APC baseline) Gauss-Jordan inversions.  No BLAS/LAPACK
//! crate is available offline, so this module implements everything the
//! solvers need from scratch:
//!
//! * [`Matrix`] — row-major f32 dense matrix,
//! * [`blas`] — blocked gemm/gemv/axpy primitives,
//! * [`simd`] — the runtime-dispatched kernel layer under [`blas`]
//!   (AVX2+FMA or a lane-structured scalar fallback, bit-identical by
//!   construction; `DAPC_FORCE_SCALAR=1` forces the scalar path),
//! * [`qr`] — Householder QR (economy form, paper eq. (1)),
//! * [`triangular`] — forward/backward substitution (paper eqs. (2)-(3)),
//! * [`inverse`] — Gauss-Jordan elimination with partial pivoting [18],
//! * [`norms`] — vector/matrix norms, MSE/MAE helpers used by metrics.
//!
//! These mirror `python/compile/kernels/linalg.py` one-for-one; the
//! integration tests cross-check the two implementations through the PJRT
//! runtime.

pub mod blas;
pub mod inverse;
mod matrix;
pub mod norms;
pub mod qr;
pub mod simd;
pub mod triangular;

pub use matrix::Matrix;
