//! Distributed Gradient Descent baseline (Fig. 2's third curve, [5]).
//!
//! Each partition computes its local least-squares gradient
//! `g_j = A_j^T (A_j x - b_j)`; the driver applies
//! `x <- x - alpha * sum_j g_j`.  The epoch loop itself lives in
//! [`super::driver::drive_dgd`] (shared with the distributed cluster);
//! this facade runs it over an [`InProcessBackend`] with the same
//! partitioning and engine interface as the APC solvers so the comparison
//! is apples-to-apples.

use crate::error::Result;
use crate::sparse::CsrMatrix;

use super::driver::{drive_dgd, InProcessBackend};
use super::engine::ComputeEngine;
use super::report::{SolveOptions, SolveReport};
use super::Solver;

/// DGD solver over the same partition layout as APC.  A step size of
/// `options.dgd_step <= 0` selects the driver's conservative Gershgorin
/// bound ([`super::driver::auto_dgd_step`]).
#[derive(Debug, Clone)]
pub struct DgdSolver {
    pub options: SolveOptions,
}

impl DgdSolver {
    pub fn new(options: SolveOptions) -> Self {
        Self { options }
    }
}

impl Solver for DgdSolver {
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport> {
        let mut backend = InProcessBackend::new(engine, j);
        drive_dgd(&mut backend, a, b, &self.options)
    }

    fn name(&self) -> &'static str {
        "dgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::engine::NativeEngine;
    use crate::sparse::generate::GeneratorConfig;

    #[test]
    fn dgd_reduces_mse() {
        let ds = GeneratorConfig::small_demo(16, 2).generate(9);
        let e = NativeEngine::new();
        let solver = DgdSolver::new(SolveOptions {
            epochs: 400,
            dgd_step: 0.0, // auto
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        });
        let report = solver.solve(&e, &ds.matrix, &ds.rhs, 2).unwrap();
        let tr = report.trace.unwrap();
        assert!(
            tr.final_mse().unwrap() < tr.initial_mse().unwrap() * 0.2,
            "{:?} -> {:?}",
            tr.initial_mse(),
            tr.final_mse()
        );
    }

    #[test]
    fn dgd_slower_than_apc_at_same_epochs() {
        // the Fig. 2 qualitative relationship: at equal epoch budgets APC
        // reaches far lower error than DGD
        let ds = GeneratorConfig::small_demo(24, 2).generate(10);
        let e = NativeEngine::new();
        let t = 40;
        let apc = crate::solver::DapcSolver::new(SolveOptions {
            epochs: t,
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        })
        .solve(&e, &ds.matrix, &ds.rhs, 2)
        .unwrap();
        let dgd = DgdSolver::new(SolveOptions {
            epochs: t,
            dgd_step: 0.0,
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        })
        .solve(&e, &ds.matrix, &ds.rhs, 2)
        .unwrap();
        assert!(
            apc.final_mse(&ds.x_true) < dgd.final_mse(&ds.x_true),
            "apc {} vs dgd {}",
            apc.final_mse(&ds.x_true),
            dgd.final_mse(&ds.x_true)
        );
    }

    #[test]
    fn explicit_step_size_used() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(11);
        let e = NativeEngine::new();
        let solver = DgdSolver::new(SolveOptions {
            epochs: 1,
            dgd_step: 1e-5,
            ..Default::default()
        });
        let r = solver.solve(&e, &ds.matrix, &ds.rhs, 1).unwrap();
        assert_eq!(r.epochs, 1);
    }
}
