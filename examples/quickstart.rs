//! Quickstart: solve a small consistent sparse system with the paper's
//! decomposed APC on the native engine.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dapc::prelude::*;
use dapc::sparse::generate::GeneratorConfig;

fn main() -> Result<()> {
    // 1. A consistent overdetermined system with a known solution:
    //    square base A0 (64x64) + augmented rows (paper §4, eq. (8)).
    let ds = GeneratorConfig::small_demo(64, 4).generate(42);
    println!(
        "dataset: {}x{} ({} nnz, {:.2}% sparse), known x_true",
        ds.matrix.rows(),
        ds.matrix.cols(),
        ds.matrix.nnz(),
        ds.matrix.sparsity_pct()
    );

    // 2. Solve with Algorithm 1: J = 4 partitions, T = 50 epochs.
    let opts = SolveOptions {
        epochs: 50,
        eta: 0.9,
        gamma: 0.9,
        x_true: Some(ds.x_true.clone()),
        ..Default::default()
    };
    let engine = NativeEngine::new();
    let report = DapcSolver::new(opts).solve(&engine, &ds.matrix, &ds.rhs, 4)?;

    // 3. Inspect the result.
    println!("{}", report.summary());
    println!("final MSE vs x_true: {:.3e}", report.final_mse(&ds.x_true));
    if let Some(trace) = &report.trace {
        println!(
            "MSE: epoch 0 = {:.3e}  ->  epoch {} = {:.3e}",
            trace.initial_mse().unwrap(),
            report.epochs,
            trace.final_mse().unwrap()
        );
    }
    assert!(report.final_mse(&ds.x_true) < 1e-6);
    println!("quickstart OK");
    Ok(())
}
